"""Ragged FilterBank: per-slot active particle counts from kernels to the
scheduler.

The equivalence spine: a uniform ragged bank (every slot full-width) is
*bit-identical* to the dense FilterBank across policies and backends; a
partial slot's statistics are those of a width-n filter (masked lanes carry
weight exactly 0 and never win a resampling draw); admission counts are
traced (no recompile per size); the continuous-batching scheduler serves
key-derived heterogeneous budgets and accounts the padding it avoids.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FilterBank,
    FilterConfig,
    SMCSpec,
    get_policy,
)
from repro.core.tracking import (
    TrackerConfig,
    make_multi_tracker_filter,
    make_tracker_spec,
)
from repro.data.synthetic_video import VideoConfig, generate_video

FRAMES, H, W, P = 8, 64, 64, 256


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )[0]


def _banks(policy, backend="jnp", ess_threshold=1.0, slots=3):
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend=backend)
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])[:slots]
    spec = make_tracker_spec(cfg, policy, starts=starts)
    fc = FilterConfig(
        policy=policy, backend=backend, ess_threshold=ess_threshold
    )
    return FilterBank(spec, fc, num_slots=slots), FilterBank(
        spec, fc, num_slots=slots
    )


# The acceptance spine: full-width ragged == dense, bit for bit, for every
# policy/backend combination the bank supports, including the adaptive
# (sub-1.0 threshold) resampling path.
@pytest.mark.parametrize(
    "pname,backend,thr",
    [
        ("fp32", "jnp", 1.0),
        ("fp32", "jnp", 0.5),
        ("fp32", "pallas", 1.0),
        ("bf16", "jnp", 1.0),
        ("bf16", "pallas", 1.0),
        ("fp16", "jnp", 0.5),
        ("fp16", "pallas", 1.0),
        ("bf16_mixed", "jnp", 1.0),
    ],
)
def test_uniform_ragged_bit_identical_to_dense(video, pname, backend, thr):
    pol = get_policy(pname)
    dense, ragged = _banks(pol, backend=backend, ess_threshold=thr)
    fd, od = dense.run(jax.random.key(1), video, P)
    fr, orr = ragged.run(
        jax.random.key(1), video, P,
        n_active=jnp.full((3,), P, jnp.int32),
    )
    assert fr.n_active is not None and fd.n_active is None
    np.testing.assert_array_equal(
        np.asarray(od.estimate["pos"], np.float64),
        np.asarray(orr.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(fd.log_weights, np.float64),
        np.asarray(fr.log_weights, np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(fd.particles["pos"], np.float64),
        np.asarray(fr.particles["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(od.ess, np.float64), np.asarray(orr.ess, np.float64)
    )
    np.testing.assert_array_equal(
        np.asarray(od.resampled), np.asarray(orr.resampled)
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_partial_slots_mask_invariants(video, backend):
    """Lanes past a slot's count stay at -inf log-weight / zero weight
    through every step, ESS is bounded by the budget, estimates stay
    finite, and a slot's active lanes never inherit an inactive lane."""
    pol = get_policy("fp32")
    budgets = jnp.asarray([P, 64, 16], jnp.int32)
    _, bank = _banks(pol, backend=backend, ess_threshold=0.5)
    state = bank.init(jax.random.key(1), P, n_active=budgets)

    # Poison the inactive lanes with a sentinel position: if resampling
    # ever drew an inactive ancestor, the sentinel would surface in an
    # active lane after the gather.
    sentinel = 7777.0
    lane = np.arange(P)
    mask = lane[None, :] >= np.asarray(budgets)[:, None]
    pos = np.array(state.particles["pos"])
    pos[mask] = sentinel
    state = state._replace(
        particles={"pos": jnp.asarray(pos)}
    )

    for t in range(FRAMES):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 3)
        state, out = bank.jit_step_shared(state, video[t], ks)
        lw = np.asarray(state.log_weights)
        assert np.isneginf(lw[1, 64:]).all()
        assert np.isneginf(lw[2, 16:]).all()
        assert np.isfinite(lw[1, :64]).all() or out.resampled[1]
        ess = np.asarray(out.ess)
        assert ess[1] <= 64 + 1e-3 and ess[2] <= 16 + 1e-3
        assert np.isfinite(np.asarray(out.estimate["pos"])).all()
        p = np.asarray(state.particles["pos"])
        # the tracker clips positions to the frame, so a surviving
        # sentinel could only have come from gathering an inactive lane
        assert (p[1, :64] < H + 1).all() and (p[2, :16] < H + 1).all()


def test_partial_slot_estimate_ignores_inactive_lanes(video):
    """The weighted-mean estimate of a budget-n slot uses only its active
    prefix: poisoned inactive lanes must not move it."""
    pol = get_policy("fp32")
    budgets = jnp.asarray([P, 64, 16], jnp.int32)
    _, bank = _banks(pol)
    state_a = bank.init(jax.random.key(1), P, n_active=budgets)
    pos = np.asarray(state_a.particles["pos"])
    mask = np.arange(P)[None, :] >= np.asarray(budgets)[:, None]
    poisoned = pos.copy()
    poisoned[mask] = 3333.0
    state_b = state_a._replace(particles={"pos": jnp.asarray(poisoned)})
    ks = jax.random.split(jax.random.key(3), 3)
    _, out_a = bank.jit_step_shared(state_a, video[0], ks)
    _, out_b = bank.jit_step_shared(state_b, video[0], ks)
    np.testing.assert_array_equal(
        np.asarray(out_a.estimate["pos"]), np.asarray(out_b.estimate["pos"])
    )
    np.testing.assert_array_equal(
        np.asarray(out_a.ess), np.asarray(out_b.ess)
    )


def test_init_slot_traced_count_no_recompile(video):
    """Admission at a new particle budget reuses the compiled reset — the
    recompile-free contract the scheduler relies on."""
    pol = get_policy("fp32")
    _, bank = _banks(pol)
    state = bank.init(
        jax.random.key(1), P, n_active=jnp.full((3,), P, jnp.int32)
    )
    state = bank.jit_init_slot(
        state, jnp.int32(1), jax.random.key(5), jnp.int32(96)
    )
    n0 = bank.jit_init_slot._cache_size()
    state = bank.jit_init_slot(
        state, jnp.int32(2), jax.random.key(6), jnp.int32(17)
    )
    assert bank.jit_init_slot._cache_size() == n0, "recompiled on new count"
    assert np.asarray(state.n_active).tolist() == [P, 96, 17]
    lw = np.asarray(state.log_weights)
    np.testing.assert_allclose(lw[1, :96], -np.log(96.0), rtol=1e-6)
    assert np.isneginf(lw[1, 96:]).all()
    np.testing.assert_allclose(lw[2, :17], -np.log(17.0), rtol=1e-6)
    # a reset without a count restores full width
    state = bank.jit_init_slot(state, jnp.int32(2), jax.random.key(7))
    assert np.asarray(state.n_active).tolist() == [P, 96, P]
    # and the bank keeps stepping
    ks = jax.random.split(jax.random.key(8), 3)
    _, out = bank.jit_step_shared(state, video[0], ks)
    assert np.isfinite(np.asarray(out.estimate["pos"])).all()


def test_ragged_validation():
    pol = get_policy("fp32")
    dense, ragged = _banks(pol)
    with pytest.raises(ValueError, match="one count per slot"):
        ragged.init(jax.random.key(0), P, n_active=jnp.asarray([P, P]))
    with pytest.raises(ValueError, match=r"\[0, 256\]"):
        ragged.init(
            jax.random.key(0), P, n_active=jnp.asarray([P, P, P + 1])
        )
    state = dense.init(jax.random.key(0), P)
    with pytest.raises(ValueError, match="ragged bank"):
        dense.init_slot(state, 0, jax.random.key(1), n_active=8)
    # a concrete re-admission count must also fit the lane width (an
    # oversized count would silently mis-scale the systematic grid)
    rstate = ragged.init(
        jax.random.key(0), P, n_active=jnp.full((3,), P, jnp.int32)
    )
    with pytest.raises(ValueError, match=r"\[0, 256\]"):
        ragged.init_slot(rstate, 0, jax.random.key(1), n_active=2 * P)


def test_custom_resampler_without_masked_form_rejected():
    """A registered resampler with no masked (count-aware) form cannot run
    ragged: its dense grid would silently truncate the active mass."""
    from repro.core import resampling

    @resampling.register_resampler("_test_ragged_echo")
    def _echo(key, weights, policy, num_samples=None):
        return jnp.arange(weights.shape[-1], dtype=jnp.int32)

    try:
        pol = get_policy("fp32")
        spec = make_tracker_spec(
            TrackerConfig(num_particles=P, height=H, width=W), pol
        )
        bank = FilterBank(
            spec,
            FilterConfig(policy=pol, resampler="_test_ragged_echo"),
            num_slots=2,
        )
        # dense use stays fine
        bank.init(jax.random.key(0), P)
        with pytest.raises(ValueError, match="no masked"):
            bank.init(
                jax.random.key(0), P, n_active=jnp.asarray([P, 64])
            )
    finally:
        del resampling.RESAMPLERS["_test_ragged_echo"]


@pytest.mark.parametrize("resampler", ["stratified", "multinomial"])
def test_masked_cdf_resamplers_cover_whole_active_prefix(resampler):
    """Regression: the masked stratified/multinomial draws must span the
    *active* CDF.  A dense 1/P grid truncated by the mask only ever probed
    u < n/P, so particles in the top of the active mass could never be
    selected — with uniform weights over a half-width prefix, ancestors
    would all land in the bottom half."""
    from repro.core import resampling

    pol = get_policy("fp32")
    n, width = 128, 256
    w = jnp.zeros((1, width)).at[0, :n].set(1.0 / n)
    keys = jax.random.split(jax.random.key(0), 1)
    fn = resampling.MASKED_RESAMPLERS[resampler]
    anc = np.asarray(fn(keys, w, pol, jnp.asarray([n], jnp.int32)))[0, :n]
    assert (anc < n).all()  # never an inactive ancestor
    assert anc.max() > n // 2  # top half of the active mass is reachable
    # stratified at full width stays bitwise the dense draw
    if resampler == "stratified":
        full = np.asarray(
            fn(keys, w, pol, jnp.asarray([width], jnp.int32))
        )
        dense = np.asarray(
            jax.vmap(
                lambda k, row: resampling.stratified(k, row, pol)
            )(keys, w)
        )
        np.testing.assert_array_equal(full, dense)


def test_ragged_bank_stratified_end_to_end(video):
    """A ragged bank on a non-systematic CDF resampler filters sanely
    (finite estimates, mask invariants hold)."""
    pol = get_policy("fp32")
    cfg = TrackerConfig(
        num_particles=P, height=H, width=W, resampler="stratified"
    )
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0]])
    spec = make_tracker_spec(cfg, pol, starts=starts)
    bank = FilterBank(
        spec,
        FilterConfig(policy=pol, resampler="stratified"),
        num_slots=2,
    )
    state = bank.init(
        jax.random.key(1), P, n_active=jnp.asarray([P, 48], jnp.int32)
    )
    for t in range(4):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 2)
        state, out = bank.jit_step_shared(state, video[t], ks)
    assert np.isfinite(np.asarray(out.estimate["pos"])).all()
    assert np.isneginf(np.asarray(state.log_weights)[1, 48:]).all()


def test_multi_tracker_budgets_still_track():
    """Per-target budgets: a generously-budgeted and a lean target both
    lock onto their objects (the lean one pays fewer lanes)."""
    pol = get_policy("fp32")
    base = dict(num_frames=24, height=96, width=96)
    va, ta = generate_video(
        jax.random.key(0), VideoConfig(start=(20.0, 20.0), **base)
    )
    vb, tb = generate_video(
        jax.random.key(1), VideoConfig(start=(70.0, 60.0), **base)
    )
    video2 = jnp.maximum(va, vb)
    starts = jnp.stack([ta[0], tb[0]])
    bank = make_multi_tracker_filter(
        TrackerConfig(num_particles=1024, height=96, width=96),
        pol,
        starts,
        budgets=jnp.asarray([1024, 192]),
    )
    assert bank.default_n_active is not None
    _, outs = jax.jit(lambda k, v: bank.run(k, v, 1024))(
        jax.random.key(2), video2
    )
    est = np.asarray(outs.estimate["pos"], np.float64)  # (T, 2, 2)
    truth = np.stack([np.asarray(ta), np.asarray(tb)], axis=1)
    rmse = np.sqrt(((est - truth) ** 2).sum(-1).mean(0))
    assert (rmse < 6.0).all(), rmse
    ess = np.asarray(outs.ess)
    assert (ess[:, 1] <= 192 + 1e-2).all()


def test_multi_tracker_budgets_validation():
    pol = get_policy("fp32")
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0]])
    with pytest.raises(ValueError, match="one count per target"):
        make_multi_tracker_filter(
            TrackerConfig(num_particles=64), pol, starts,
            budgets=jnp.asarray([64]),
        )


def test_ragged_scheduler_serves_heterogeneous_budgets():
    """serve --smc with a particle range: every request served once with a
    key-derived size-class budget; the best-particle extraction stays
    inside each request's active prefix; padding waste is accounted."""
    from repro.launch.serve import run_continuous_batching

    steps = 5

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok,
            reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    spec = SMCSpec(init, transition, loglik)
    out = {}
    for mode in (False, True):
        bank = FilterBank(
            spec,
            FilterConfig(policy=get_policy("fp32"), ess_threshold=0.5),
            num_slots=4,
        )
        out[mode] = run_continuous_batching(
            bank,
            num_requests=4,  # one slot per request: sync == async schedules
            max_steps=steps,
            particles=(2, 8),
            key=jax.random.key(7),
            arrival_every=1,
            min_steps=steps,  # equal step budgets: no mid-admission retire
            async_admit=mode,
        )
    for mode, stats in out.items():
        results = stats["results"]
        assert [r["id"] for r in results] == list(range(4))
        for r in results:
            assert r["particles"] in (2, 4, 8)
            assert r["tokens"].shape == (r["steps"],)
        assert len({r["particles"] for r in results}) > 1, (
            "key-derived budgets should mix size classes"
        )
        assert stats["active_particle_ticks"] < stats["padded_particle_ticks"]
        assert 0.0 < stats["padding_waste"] < 1.0
    # sync and async draw the same schedule from the same key
    for rs, ra in zip(out[False]["results"], out[True]["results"]):
        assert rs["particles"] == ra["particles"]
        assert rs["steps"] == ra["steps"]
        np.testing.assert_array_equal(rs["tokens"], ra["tokens"])


def test_dense_scheduler_reports_zero_waste():
    """A single-count workload keeps the dense bank and zero padding."""
    from repro.launch.serve import run_continuous_batching

    steps = 3

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok, reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    spec = SMCSpec(init, transition, lambda p, o, s: p["reward"])
    bank = FilterBank(
        spec, FilterConfig(policy=get_policy("fp32")), num_slots=2
    )
    stats = run_continuous_batching(
        bank,
        num_requests=3,
        max_steps=steps,
        particles=4,
        key=jax.random.key(0),
    )
    assert stats["padding_waste"] == 0.0
    assert all(r["particles"] == 4 for r in stats["results"])
