"""Helper: run a snippet in a subprocess with N forced host devices.

Multi-device tests must not pollute the main pytest process (jax locks the
device count at first init), so they execute in a child interpreter.
"""

from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(snippet: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
        )
    return proc.stdout
