"""Optimizer correctness: AdamW vs numpy reference, clipping, skip-on-nan."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.optim.schedule import make_schedule


def _np_adamw(p, g, m, v, step, lr, cfg):
    gn = np.sqrt((g**2).sum())
    scale = min(1.0, cfg.clip_norm / max(gn, 1e-9))
    g = g * scale
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m / (1 - cfg.b1**step)
    vhat = v / (1 - cfg.b2**step)
    p = p - lr * (mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
    return p, m, v


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(m_dtype=jnp.float32, v_dtype=jnp.float32, clip_norm=1e9)
    rng = np.random.default_rng(0)
    p_np = rng.normal(size=(32,)).astype(np.float32)
    params = {"w": jnp.asarray(p_np)}
    state = init_opt_state(params, cfg)
    m_np = np.zeros(32, np.float32)
    v_np = np.zeros(32, np.float32)
    for step in range(1, 6):
        g_np = rng.normal(size=(32,)).astype(np.float32)
        params, state, _ = adamw_update(
            params, {"w": jnp.asarray(g_np)}, state, jnp.float32(1e-2), cfg
        )
        p_np, m_np, v_np = _np_adamw(p_np, g_np, m_np, v_np, step, 1e-2, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), p_np, rtol=2e-5, atol=1e-6)


def test_clipping_applied():
    cfg = OptConfig(clip_norm=1.0, m_dtype=jnp.float32)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init_opt_state(params, cfg)
    big = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(params, big, state, jnp.float32(0.1), cfg)
    assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip


def test_nonfinite_grads_skip_update():
    cfg = OptConfig(m_dtype=jnp.float32)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = init_opt_state(params, cfg)
    bad = {"w": jnp.asarray([1.0, jnp.nan, 1.0, 1.0])}
    new_params, new_state, metrics = adamw_update(
        params, bad, state, jnp.float32(0.1), cfg
    )
    assert float(metrics["finite"]) == 0.0
    np.testing.assert_array_equal(
        np.asarray(new_params["w"]), np.asarray(params["w"])
    )
    assert bool(jnp.isfinite(new_state["m"]["w"]).all())


def test_bf16_first_moment_close_to_fp32():
    """The low-precision-m trick: trajectories track the fp32 optimizer."""
    cfg16 = OptConfig(m_dtype=jnp.bfloat16, clip_norm=1e9)
    cfg32 = OptConfig(m_dtype=jnp.float32, clip_norm=1e9)
    rng = np.random.default_rng(1)
    p16 = {"w": jnp.ones((64,), jnp.float32)}
    p32 = {"w": jnp.ones((64,), jnp.float32)}
    s16 = init_opt_state(p16, cfg16)
    s32 = init_opt_state(p32, cfg32)
    for step in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        p16, s16, _ = adamw_update(p16, g, s16, jnp.float32(1e-2), cfg16)
        p32, s32, _ = adamw_update(p32, g, s32, jnp.float32(1e-2), cfg32)
    diff = np.abs(np.asarray(p16["w"]) - np.asarray(p32["w"])).max()
    drift = np.abs(np.asarray(p32["w"]) - 1.0).max()
    assert diff < 0.1 * drift, (diff, drift)


def test_schedules():
    sched = make_schedule("cosine", peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(100))) < 0.2
    const = make_schedule("constant", peak_lr=0.5, warmup_steps=10)
    assert abs(float(const(jnp.int32(50))) - 0.5) < 1e-7
