"""Per-arch smoke tests (reduced configs) + decode/forward consistency.

Every assigned architecture instantiates a reduced same-family config and
runs one forward + one train step on CPU, asserting shapes and finiteness;
recurrent/cached decode must agree with the full-sequence forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.core.precision import get_policy
from repro.models import model as M
from repro.optim import init_opt_state
from repro.train import TrainConfig, make_train_step

POL = get_policy("bf16_mixed")
B, S = 2, 64

ALL_ARCHS = list_archs()


def make_batch(cfg, key=None):
    k = key or jax.random.key(0)
    if cfg.frontend == "frames":
        return {
            "frames": jax.random.normal(k, (B, S, cfg.frame_dim), jnp.float32),
            "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
            "mask": jax.random.bernoulli(k, 0.3, (B, S)).astype(jnp.float32),
        }
    if cfg.frontend == "vlm":
        return {
            "tokens": jax.random.randint(
                k, (B, S - cfg.vlm_image_seq), 0, cfg.vocab_size
            ),
            "patch_embeds": jax.random.normal(
                k, (B, cfg.vlm_image_seq, cfg.d_model), jnp.float32
            ),
        }
    return {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    batch = make_batch(cfg)

    logits, aux = jax.jit(lambda p, b: M.forward(p, b, cfg, POL))(params, batch)
    s_out = S if cfg.frontend != "vlm" else S
    assert logits.shape == (B, s_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    tcfg = TrainConfig(microbatches=2, total_steps=10, warmup_steps=2)
    opt = init_opt_state(params, tcfg.opt)
    step_fn = jax.jit(make_train_step(cfg, POL, tcfg))
    # step 1: the warmup schedule gives lr=0 at step 0 by construction
    params2, opt2, metrics = step_fn(params, opt, batch, jnp.int32(1))
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert float(metrics["finite"]) == 1.0, arch
    # parameters actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda a, b: float(jnp.sum(jnp.abs(a - b))), params, params2
        ),
    )
    assert delta > 0.0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_decode_step(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step")
    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    cache = M.init_cache(cfg, B, 128, jnp.float32)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c: M.decode_step(p, t, jnp.int32(0), c, cfg, POL)
    )(params, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize(
    "arch", ["minitron-8b", "rwkv6-7b", "zamba2-2.7b", "gemma3-27b",
             "deepseek-moe-16b"]
)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full forward (fp32).

    MoE capacity is raised so no token→expert pair drops: drops are
    seqlen-dependent by design (forward groups the whole sequence, decode
    one token), so the equivalence only holds drop-free — and with a
    random-init router the near-tie top-k makes drop counts environment-
    sensitive.
    """
    cfg = reduced_config(get_config(arch))
    if cfg.num_experts:
        cfg = reduced_config(
            get_config(arch), capacity_factor=float(cfg.num_experts)
        )
    pol = get_policy("fp32")
    s = 16
    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(2), (B, s), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, {"tokens": toks}, cfg, pol)
    cache = M.init_cache(cfg, B, s, jnp.float32)
    outs = []
    dstep = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )
    for i in range(s):
        lg, cache = dstep(params, toks[:, i], jnp.int32(i), cache)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), atol=5e-4, rtol=1e-3
    )


def test_moe_capacity_drop_policy_pinned():
    """Pin the drop policy itself, not just the drop-free case.

    Token-choice with static capacity: routed pairs are stably sorted by
    expert, and within an expert the first ``cap`` pairs in flat
    (token-major) order are kept — every later pair contributes exactly
    zero.  Verified three ways: (1) a rigged all-to-one-expert routing
    against a hand-built reference (which tokens drop, and that kept
    tokens get the plain expert FFN), (2) scatter vs einsum dispatch agree
    under drops (independent mechanisms, same slot assignment), and (3)
    teacher-forced decode == forward at a *dropping* capacity when both
    group the same tokens (s=1), so the decode path applies the identical
    policy.
    """
    import dataclasses

    from repro.models.moe import _einsum_dispatch, _expert_ffn, _group_dispatch

    cfg = reduced_config(get_config("deepseek-moe-16b"), capacity_factor=0.5)
    e, k, d, f = cfg.num_experts, cfg.experts_per_token, cfg.d_model, cfg.moe_d_ff
    t = 8
    cap = int(t * k / e * cfg.capacity_factor) + 1  # the policy's capacity
    kw = jax.random.split(jax.random.key(0), 3)
    w = {
        "wi_gate": 0.1 * jax.random.normal(kw[0], (e, d, f), jnp.float32),
        "wi_up": 0.1 * jax.random.normal(kw[1], (e, d, f), jnp.float32),
        "wo": 0.1 * jax.random.normal(kw[2], (e, f, d), jnp.float32),
    }
    xt = jax.random.normal(jax.random.key(1), (t, d), jnp.float32)

    # (1) all t*k pairs routed to expert 0 -> only the first cap pairs (in
    # token-major order) survive; token i keeps min(k, max(0, cap - i*k))
    # of its k copies, each gate-weight 1.
    ids = jnp.zeros((t, k), jnp.int32)
    gates = jnp.ones((t, k), jnp.float32)
    out = _group_dispatch(xt, ids, gates, w, cfg)
    kept = np.minimum(k, np.maximum(0, cap - np.arange(t) * k))
    w0 = {name: v[:1] for name, v in w.items()}
    ffn0 = _expert_ffn(w0, xt[None], cfg.act)[0]
    expect = np.asarray(ffn0) * kept[:, None]
    assert kept.max() == k and kept.min() == 0  # drops actually happen
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)

    # (2) scatter and one-hot-einsum dispatch implement one drop policy
    logits = jax.random.normal(jax.random.key(2), (t, e), jnp.float32)
    gates_r, ids_r = jax.lax.top_k(jax.nn.softmax(logits), k)
    out_scatter = _group_dispatch(xt, ids_r, gates_r, w, cfg)
    out_einsum = _einsum_dispatch(xt, ids_r, gates_r, w, cfg)
    np.testing.assert_allclose(
        np.asarray(out_scatter), np.asarray(out_einsum), rtol=1e-4, atol=1e-5
    )

    # (3) decode parity at a dropping capacity: with s=1 the forward groups
    # one token exactly like decode does, cap = int(k/e * 0.5) + 1 = 1 < k,
    # so second-choice experts drop in *both* paths identically.
    pol = get_policy("fp32")
    assert int(k / e * cfg.capacity_factor) + 1 < k
    params = M.init_params(jax.random.key(1), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, {"tokens": toks}, cfg, pol)
    cache = M.init_cache(cfg, B, 1, jnp.float32)
    dec, _ = M.decode_step(params, toks[:, 0], jnp.int32(0), cache, cfg, pol)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full[:, 0]), atol=5e-4, rtol=1e-3
    )


def test_ring_buffer_cache_smaller_than_context():
    """Sliding-window layers allocate window-sized ring caches."""
    cfg = reduced_config(get_config("gemma3-27b"))
    assert cfg.window and cfg.window < 4096
    cache = M.init_cache(cfg, B, 4096, jnp.float32)
    # local caches: (n_super, g-1, B, window, kv, hd)
    local_k = cache["supers_local"]["kv"]["k"]
    assert local_k.shape[3] == cfg.window
    glob_k = cache["supers_global"]["kv"]["k"]
    assert glob_k.shape[2] == 4096


def test_param_counts_match_expectation():
    """Analytic parameter counts are pinned (regression guard for the spec
    trees).  Values follow from the assigned configs; nameplate sizes that
    differ (command-r '35B' -> 30.3B from the given dims; internvl '76B'
    counts the stubbed 6B ViT frontend) are documented in DESIGN.md."""
    expect_b = {
        "command-r-35b": 30.28,
        "minitron-8b": 9.88,
        "stablelm-12b": 12.14,
        "gemma3-27b": 27.01,
        "zamba2-2.7b": 2.34,
        "grok-1-314b": 315.68,
        "deepseek-moe-16b": 16.88,
        "internvl2-76b": 70.62,
        "hubert-xlarge": 1.26,
        "rwkv6-7b": 7.53,
    }
    for arch, nb in expect_b.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - nb) / nb < 0.01, (arch, n, nb)


def test_moe_active_params_smaller():
    cfg = get_config("grok-1-314b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
