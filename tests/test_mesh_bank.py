"""Mesh × bank composition on 8 forced host devices.

The equivalence suite for the meshed FilterBank: a meshed B=1 bank in
``exact`` mode is bit-comparable to the meshed ParticleFilter; the
``local`` RNA scheme agrees with the unmeshed bank at the estimator level;
the continuous-batching scheduler admits/retires over a sharded bank
(synchronous and double-buffered async paths serving the same requests);
and slot/particle counts must divide the mesh.
"""

import pytest

from tests._mp import run_with_devices

BANK1_EXACT_BITWISE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, ParticleFilter, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, _ = generate_video(jax.random.key(0),
                          VideoConfig(num_frames=8, height=64, width=64))
pol = get_policy("{policy}")
spec = make_tracker_spec(
    TrackerConfig(num_particles=512, height=64, width=64), pol)

# meshed single filter: 512 particles over 8 devices
mesh1 = make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
flt = ParticleFilter(spec, FilterConfig(
    policy=pol, mesh=mesh1, axis="data", scheme="exact"))
# meshed B=1 bank: 1 slot on "data", particles over 8 "model" devices
mesh2 = make_mesh((1, 8), ("data", "model"),
                  axis_types=(jax.sharding.AxisType.Auto,) * 2)
bank = FilterBank(spec, FilterConfig(policy=pol, mesh=mesh2, scheme="exact"),
                  num_slots=1)

k = jax.random.key(1)
sf, sb = flt.init(k, 512), bank.init(k, 512)
np.testing.assert_array_equal(np.asarray(sf.particles["pos"]),
                              np.asarray(sb.particles["pos"][0]))
for t in range(8):
    kk = jax.random.key(100 + t)
    sf, of = flt.jit_step(sf, video[t], kk)
    sb, ob = bank.jit_step_shared(sb, video[t], kk[None])
    np.testing.assert_array_equal(np.asarray(of.estimate["pos"]),
                                  np.asarray(ob.estimate["pos"][0]))
    np.testing.assert_array_equal(np.asarray(of.ess),
                                  np.asarray(ob.ess[0]))
    np.testing.assert_array_equal(np.asarray(sf.particles["pos"]),
                                  np.asarray(sb.particles["pos"][0]))
    np.testing.assert_array_equal(np.asarray(sf.log_weights),
                                  np.asarray(sb.log_weights[0]))
print("bitwise ok")
"""


@pytest.mark.parametrize("policy", ["fp32", "fp16"])
def test_meshed_bank1_exact_bitwise_matches_meshed_filter(policy):
    out = run_with_devices(BANK1_EXACT_BITWISE.format(policy=policy), devices=8)
    assert "bitwise ok" in out


LOCAL_AGREEMENT = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_multi_tracker_filter
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, truth = generate_video(jax.random.key(0),
                              VideoConfig(num_frames=25, height=128, width=128))
pol = get_policy("fp32")
cfg = TrackerConfig(num_particles=1024, height=128, width=128)
starts = jnp.tile(jnp.asarray(truth[0])[None], (2, 1))
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
errs = {}
for name, fc in [
    ("unmeshed", FilterConfig(policy=pol)),
    ("meshed", FilterConfig(policy=pol, mesh=mesh, scheme="local")),
]:
    bank = make_multi_tracker_filter(cfg, pol, starts, fc)
    state = bank.init(jax.random.key(1), 1024)
    ests = []
    for t in range(25):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 2)
        state, out = bank.jit_step_shared(state, video[t], ks)
        ests.append(np.asarray(out.estimate["pos"]))
    traj = np.stack(ests)                       # (T, 2, 2)
    assert np.isfinite(traj).all()
    err = np.sqrt(np.mean(np.sum(
        (traj - np.asarray(truth[:25])[:, None]) ** 2, -1), 0))
    errs[name] = err
# both banks track the same truth; the RNA scheme is a different (unbiased)
# resampler, so agreement is at the estimator level, not bitwise
for name, err in errs.items():
    assert (err < 3.0).all(), (name, err)
print("estimator agreement ok", errs)
"""


def test_meshed_bank_local_estimator_agreement():
    out = run_with_devices(LOCAL_AGREEMENT, devices=8)
    assert "estimator agreement ok" in out


PALLAS_MATCHES_JNP = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, _ = generate_video(jax.random.key(0),
                          VideoConfig(num_frames=6, height=64, width=64))
pol = get_policy("fp32")
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
est = {}
for backend in ("jnp", "pallas"):
    spec = make_tracker_spec(
        TrackerConfig(num_particles=512, height=64, width=64,
                      backend=backend), pol,
        starts=jnp.asarray([[20.0, 20.0], [44.0, 44.0], [32.0, 32.0],
                            [16.0, 48.0]]))
    bank = FilterBank(
        spec, FilterConfig(policy=pol, backend=backend, mesh=mesh,
                           scheme="local"), num_slots=4)
    state = bank.init(jax.random.key(1), 512)
    for t in range(6):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 4)
        state, out = bank.jit_step_shared(state, video[t], ks)
    est[backend] = np.asarray(out.estimate["pos"], np.float64)
    assert np.isfinite(est[backend]).all()
# fused shard-local kernels vs pure-jnp shard-local path: same fp32
# reductions per shard, same u0 derivation -> estimates agree tightly
np.testing.assert_allclose(est["pallas"], est["jnp"], atol=1e-1)
print("pallas shard-local ok")
"""


def test_meshed_bank_pallas_shard_local_kernels():
    out = run_with_devices(PALLAS_MATCHES_JNP, devices=8)
    assert "pallas shard-local ok" in out


SCHEDULER_SHARDED = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, SMCSpec, get_policy
from repro.compat import make_mesh
from repro.launch.serve import run_continuous_batching

STEPS = 6

def make_toy_decode_spec():
    # decode-shaped state (tok/reward/cum_reward/seq) without a model:
    # exercises reset-on-shard, per-slot retire readback, and best-particle
    # extraction at subprocess speed.
    def init(key, n):
        del key
        return dict(tok=jnp.zeros((n,), jnp.int32),
                    reward=jnp.zeros((n,), jnp.float32),
                    cum_reward=jnp.zeros((n,), jnp.float32),
                    seq=jnp.zeros((n, STEPS), jnp.int32))
    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(jax.random.fold_in(key, 1),
                                    p["reward"].shape)
        pos = jnp.minimum(step, STEPS - 1)
        return dict(tok=tok, reward=reward,
                    cum_reward=p["cum_reward"] + reward,
                    seq=p["seq"].at[:, pos].set(tok))
    def loglik(p, obs, step):
        del obs, step
        return p["reward"]
    def summary(p, w):
        return dict(reward=jnp.sum(w * p["reward"]))
    return SMCSpec(init, transition, loglik, summary=summary)

mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
spec = make_toy_decode_spec()
stats = {}
for mode in (False, True):
    bank = FilterBank(
        spec, FilterConfig(policy=get_policy("fp32"), ess_threshold=0.5,
                           mesh=mesh, scheme="local"), num_slots=4)
    stats[mode] = run_continuous_batching(
        bank, num_requests=7, max_steps=STEPS, particles=4,
        key=jax.random.key(0), arrival_every=1, async_admit=mode)
for mode, st in stats.items():
    results = st["results"]
    # every request served exactly once, in id order, with its own budget
    assert [r["id"] for r in results] == list(range(7)), (mode, results)
    for r in results:
        assert 1 <= r["steps"] <= STEPS
        assert r["tokens"].shape == (r["steps"],)
        assert (r["tokens"] >= 0).all() and (r["tokens"] < 100).all()
        # one request per slot at a time: service time == budget
        assert r["finished_tick"] - r["admitted_tick"] == r["steps"], (mode, r)
    assert 0.0 < st["occupancy"] <= 1.0
# the two paths draw identical budget schedules from the same key
assert ([r["steps"] for r in stats[False]["results"]]
        == [r["steps"] for r in stats[True]["results"]])
# divisibility is validated up front on the sharded bank
bank3 = FilterBank(spec, FilterConfig(mesh=mesh, scheme="local"), num_slots=3)
try:
    bank3.init(jax.random.key(0), 4)
    raise SystemExit("expected ValueError for 3 slots on a 2-wide data axis")
except ValueError as e:
    assert "num_slots" in str(e)
try:
    FilterBank(spec, FilterConfig(mesh=mesh), num_slots=4).init(
        jax.random.key(0), 5)
    raise SystemExit("expected ValueError for 5 particles on 2 model devices")
except ValueError as e:
    assert "num_particles" in str(e)
print("scheduler sharded ok")
"""


def test_scheduler_admit_retire_over_sharded_bank():
    out = run_with_devices(SCHEDULER_SHARDED, devices=4)
    assert "scheduler sharded ok" in out


RAGGED_UNIFORM_BITWISE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, _ = generate_video(jax.random.key(0),
                          VideoConfig(num_frames=6, height=64, width=64))
pol = get_policy("fp32")
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
for backend in ("jnp", "pallas"):
    spec = make_tracker_spec(
        TrackerConfig(num_particles=512, height=64, width=64,
                      backend=backend), pol,
        starts=jnp.asarray([[20.0, 20.0], [44.0, 44.0], [32.0, 32.0],
                            [16.0, 48.0]]))
    for scheme in ("exact", "local"):
        fc = FilterConfig(policy=pol, backend=backend, mesh=mesh,
                          scheme=scheme)
        dense = FilterBank(spec, fc, num_slots=4)
        ragged = FilterBank(spec, fc, num_slots=4)
        sd = dense.init(jax.random.key(1), 512)
        sr = ragged.init(jax.random.key(1), 512,
                         n_active=jnp.full((4,), 512, jnp.int32))
        for t in range(6):
            ks = jax.random.split(
                jax.random.fold_in(jax.random.key(2), t), 4)
            sd, od = dense.jit_step_shared(sd, video[t], ks)
            sr, orr = ragged.jit_step_shared(sr, video[t], ks)
        np.testing.assert_array_equal(np.asarray(od.estimate["pos"]),
                                      np.asarray(orr.estimate["pos"]))
        np.testing.assert_array_equal(np.asarray(sd.log_weights),
                                      np.asarray(sr.log_weights))
        np.testing.assert_array_equal(np.asarray(sd.particles["pos"]),
                                      np.asarray(sr.particles["pos"]))
print("meshed uniform ragged bitwise ok")
"""


def test_meshed_uniform_ragged_bitwise_matches_dense():
    """Acceptance: a full-width ragged bank == the dense bank, bit for bit,
    under the forced-8-device mesh — both distributed schemes, both the
    jnp and fused-pallas shard-local kernel paths (the masked kernels'
    zero-mass-slice handling must match the dense kernels exactly)."""
    out = run_with_devices(RAGGED_UNIFORM_BITWISE, devices=8, timeout=600)
    assert "meshed uniform ragged bitwise ok" in out


RAGGED_PARTIAL_MESHED = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, _ = generate_video(jax.random.key(0),
                          VideoConfig(num_frames=6, height=64, width=64))
pol = get_policy("fp32")
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
# budgets straddle the shard width (512/4 = 128/shard): slot 1 occupies
# less than one shard, slot 3 a shard and a half
budgets = jnp.asarray([512, 100, 256, 192], jnp.int32)
for backend in ("jnp", "pallas"):
    spec = make_tracker_spec(
        TrackerConfig(num_particles=512, height=64, width=64,
                      backend=backend), pol,
        starts=jnp.asarray([[20.0, 20.0], [44.0, 44.0], [32.0, 32.0],
                            [16.0, 48.0]]))
    for scheme in ("exact", "local"):
        bank = FilterBank(
            spec, FilterConfig(policy=pol, backend=backend, mesh=mesh,
                               scheme=scheme), num_slots=4)
        st = bank.init(jax.random.key(1), 512, n_active=budgets)
        for t in range(6):
            ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 4)
            st, out = bank.jit_step_shared(st, video[t], ks)
        lw = np.asarray(st.log_weights)
        assert np.isneginf(lw[1, 100:]).all(), (backend, scheme)
        assert np.isneginf(lw[3, 192:]).all(), (backend, scheme)
        ess = np.asarray(out.ess)
        assert (ess[1] <= 100 + 1e-2) and (ess[3] <= 192 + 1e-2), (
            backend, scheme, ess)
        est = np.asarray(out.estimate["pos"])
        assert np.isfinite(est).all(), (backend, scheme)
        # mid-flight re-admission at a traced count lands on its shard
        st = bank.jit_init_slot(st, jnp.int32(1), jax.random.key(9),
                                jnp.int32(300))
        assert np.asarray(st.n_active).tolist() == [512, 300, 256, 192]
        ks = jax.random.split(jax.random.key(10), 4)
        st, out = bank.jit_step_shared(st, video[0], ks)
        assert np.isfinite(np.asarray(out.estimate["pos"])).all()
print("meshed partial ragged ok")
"""


def test_meshed_partial_ragged_bank():
    """Partial per-slot budgets under the mesh: masked lanes stay masked
    across the pmax/psum merge, all-gather, and ring exchange, on both the
    jnp and fused-pallas shard-local kernel paths."""
    out = run_with_devices(RAGGED_PARTIAL_MESHED, devices=8, timeout=600)
    assert "meshed partial ragged ok" in out


RAGGED_SCHEDULER_SHARDED = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, SMCSpec, get_policy
from repro.compat import make_mesh
from repro.launch.serve import run_continuous_batching

STEPS = 5

def init(key, n):
    del key
    return dict(tok=jnp.zeros((n,), jnp.int32),
                reward=jnp.zeros((n,), jnp.float32),
                cum_reward=jnp.zeros((n,), jnp.float32),
                seq=jnp.zeros((n, STEPS), jnp.int32))
def transition(key, p, step):
    tok = jax.random.randint(key, p["tok"].shape, 0, 100)
    reward = jax.random.uniform(jax.random.fold_in(key, 1), p["reward"].shape)
    pos = jnp.minimum(step, STEPS - 1)
    return dict(tok=tok, reward=reward,
                cum_reward=p["cum_reward"] + reward,
                seq=p["seq"].at[:, pos].set(tok))
def loglik(p, obs, step):
    del obs, step
    return p["reward"]
def summary(p, w):
    return dict(reward=jnp.sum(w * p["reward"]))

mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
spec = SMCSpec(init, transition, loglik, summary=summary)
bank = FilterBank(
    spec, FilterConfig(policy=get_policy("fp32"), ess_threshold=0.5,
                       mesh=mesh, scheme="local"), num_slots=4)
stats = run_continuous_batching(
    bank, num_requests=6, max_steps=STEPS, particles=(2, 8),
    key=jax.random.key(0), arrival_every=1)
results = stats["results"]
assert [r["id"] for r in results] == list(range(6))
for r in results:
    assert r["particles"] in (2, 4, 8)
    assert r["tokens"].shape == (r["steps"],)
    assert (r["tokens"] >= 0).all() and (r["tokens"] < 100).all()
assert len({r["particles"] for r in results}) > 1
assert 0.0 < stats["padding_waste"] < 1.0
print("ragged scheduler sharded ok")
"""


def test_ragged_scheduler_over_sharded_bank():
    """Heterogeneous particle budgets admitted into a mesh-sharded bank:
    the end-to-end ragged serving configuration."""
    out = run_with_devices(RAGGED_SCHEDULER_SHARDED, devices=4)
    assert "ragged scheduler sharded ok" in out
