"""Fused weight-epilogue equivalence: fused == composed chain, bitwise.

The acceptance spine of the fused epilogue: with the same keys, the
one-pass kernel (normalize + ESS sums + CDF + systematic search, CDF in
VMEM) must reproduce the composed normalize → ESS → cumsum → search chain
bit for bit — per float policy, dense / banked / ragged (including
NaN/Inf-poisoned inactive lanes), at the kernel level and through the
engine, on both backends (the jnp backend dispatches the pure-jnp fused
references in ``resampling.FUSED_EPILOGUES*``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need the dev extra; the rest run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.core import FilterBank, FilterConfig, ParticleFilter, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.data.synthetic_video import VideoConfig, generate_video
from repro.kernels.epilogue import ops as epi_ops
from repro.kernels.logsumexp import ops as lse_ops
from repro.kernels.resample import ops as res_ops

DTYPES = [jnp.float32, jnp.float16, jnp.bfloat16]
FRAMES, H, W, P = 8, 64, 64, 256


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )[0]


# ---------------------------------------------------------------------------
# Kernel level


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("nbank,n", [(1, 1000), (3, 517), (2, 8192)])
def test_fused_epilogue_matches_composed_chain_bitwise(dt, nbank, n):
    """Fused kernel == normalize_stats kernel + systematic resample chain,
    every output, bit for bit, with the same keys."""
    keys = jax.random.split(jax.random.key(nbank * n), nbank)
    x = (
        jax.random.normal(jax.random.key(7), (nbank, n), jnp.float32) * 40
    ).astype(dt)
    w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_batched(x)
    anc = res_ops.systematic_resample_batched(keys, w)
    wf, ancf, lsef, mf, swf, sw2f = epi_ops.fused_epilogue_batched(keys, x)
    assert wf.dtype == dt and ancf.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(wf, np.float32), np.asarray(w, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(ancf), np.asarray(anc))
    np.testing.assert_array_equal(np.asarray(lsef), np.asarray(lse))
    np.testing.assert_array_equal(np.asarray(mf), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(swf), np.asarray(sw))
    np.testing.assert_array_equal(np.asarray(sw2f), np.asarray(sw2))


def test_fused_epilogue_single_matches_batched_row():
    x = jax.random.normal(jax.random.key(0), (3, 700), jnp.float32) * 30
    keys = jax.random.split(jax.random.key(1), 3)
    batched = epi_ops.fused_epilogue_batched(keys, x)
    for i in range(3):
        single = epi_ops.fused_epilogue(keys[i], x[i])
        for b, s in zip(batched, single):
            np.testing.assert_array_equal(
                np.asarray(b[i], np.float32), np.asarray(s, np.float32)
            )


def _junk_rows(key, nbank, width, counts, dt):
    x = (jax.random.normal(key, (nbank, width), jnp.float32) * 40).astype(dt)
    x = np.array(x)
    junk = [3e4, float("nan"), float("inf"), float("-inf")]
    for i, n in enumerate(counts):
        for j in range(n, width):
            x[i, j] = junk[(i + j) % len(junk)]
    return jnp.asarray(x)


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
def test_fused_masked_matches_unmasked_prefix_bitwise(dt):
    """Masked fused row (junk tail, incl. NaN/Inf) == unmasked fused kernel
    on the width-n prefix; inactive weights exactly 0; ancestors < n."""
    counts = [1000, 517, 128, 7]
    keys = jax.random.split(jax.random.key(3), len(counts))
    x = _junk_rows(jax.random.key(1), len(counts), 1000, counts, dt)
    n_act = jnp.asarray(counts, jnp.int32)
    wm, ancm, lsem, mm, swm, sw2m = epi_ops.fused_epilogue_masked(
        keys, x, n_act
    )
    for i, n in enumerate(counts):
        wi, anci, lsei, mi, swi, sw2i = epi_ops.fused_epilogue(
            keys[i], x[i, :n]
        )
        np.testing.assert_array_equal(
            np.asarray(wm[i, :n], np.float32), np.asarray(wi, np.float32)
        )
        np.testing.assert_array_equal(np.asarray(ancm[i, :n]), np.asarray(anci))
        assert (np.asarray(ancm[i, :n]) < n).all()
        np.testing.assert_array_equal(float(lsem[i]), float(lsei))
        np.testing.assert_array_equal(float(swm[i]), float(swi))
        np.testing.assert_array_equal(float(sw2m[i]), float(sw2i))
        assert (np.asarray(wm[i, n:], np.float32) == 0.0).all()


def test_fused_masked_full_width_bitwise_dense():
    keys = jax.random.split(jax.random.key(5), 3)
    x = jax.random.normal(jax.random.key(6), (3, 1000), jnp.float32) * 30
    full = jnp.full((3,), 1000, jnp.int32)
    a = epi_ops.fused_epilogue_masked(keys, x, full)
    b = epi_ops.fused_epilogue_batched(keys, x)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_fused_masked_matches_composed_masked_chain():
    """Masked fused == masked normalize-stats + masked resample chain."""
    counts = [700, 120, 0]
    keys = jax.random.split(jax.random.key(7), 3)
    x = _junk_rows(jax.random.key(8), 3, 700, counts, jnp.float32)
    n_act = jnp.asarray(counts, jnp.int32)
    w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_masked(x, n_act)
    anc = res_ops.systematic_resample_masked(keys, w, n_act)
    fused = epi_ops.fused_epilogue_masked(keys, x, n_act)
    for got, want in zip(fused, (w, anc, lse, m, sw, sw2)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_finalize_matches_composed_dist_tail():
    """The meshed shard-local tail: exp(x - lse) + ancestors_from_u0 ==
    the fused finalize kernel, dense and masked, full counts == dense."""
    u0 = jax.random.uniform(jax.random.key(9), (3,), jnp.float32)
    x = jax.random.normal(jax.random.key(11), (3, 512), jnp.float32) * 30
    lse = jax.vmap(lambda r: jax.scipy.special.logsumexp(r))(x)
    w_ref = jnp.exp(x - jnp.where(jnp.isfinite(lse), lse, 0.0)[:, None])
    anc_ref = res_ops.systematic_ancestors_batched(u0, w_ref)
    wf, ancf = epi_ops.fused_finalize_from_u0_batched(u0, x, lse)
    np.testing.assert_array_equal(np.asarray(wf), np.asarray(w_ref))
    np.testing.assert_array_equal(np.asarray(ancf), np.asarray(anc_ref))

    n_loc = jnp.asarray([512, 100, 0], jnp.int32)
    xm = jnp.where(jnp.arange(512)[None] < n_loc[:, None], x, -jnp.inf)
    lsem = jax.vmap(lambda r: jax.scipy.special.logsumexp(r))(xm)
    wm_ref = jnp.exp(xm - jnp.where(jnp.isfinite(lsem), lsem, 0.0)[:, None])
    ancm_ref = res_ops.systematic_ancestors_masked(u0, wm_ref, n_loc)
    wmf, ancmf = epi_ops.fused_finalize_from_u0_masked(u0, xm, lsem, n_loc)
    np.testing.assert_array_equal(np.asarray(wmf), np.asarray(wm_ref))
    np.testing.assert_array_equal(np.asarray(ancmf), np.asarray(ancm_ref))

    full = jnp.full((3,), 512, jnp.int32)
    a = epi_ops.fused_finalize_from_u0_masked(u0, x, lse, full)
    b = epi_ops.fused_finalize_from_u0_batched(u0, x, lse)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# ---------------------------------------------------------------------------
# Engine level


def _tracker(policy, backend, fused, thr=1.0, slots=None):
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend=backend)
    fc = FilterConfig(
        policy=policy,
        backend=backend,
        ess_threshold=thr,
        fused_epilogue=fused,
    )
    if slots is None:
        return ParticleFilter(make_tracker_spec(cfg, policy), fc)
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])[:slots]
    spec = make_tracker_spec(cfg, policy, starts=starts)
    return FilterBank(spec, fc, num_slots=slots)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("pname", ["fp32", "bf16", "fp16"])
def test_engine_fused_matches_composed_bitwise(video, pname, backend):
    """ParticleFilter with the fused epilogue (auto default) == forced
    composed chain, every output and the carried state, bit for bit."""
    pol = get_policy(pname)
    ff, of = jax.jit(
        lambda k, v: _tracker(pol, backend, None).run(k, v, P)
    )(jax.random.key(1), video)
    fc, oc = jax.jit(
        lambda k, v: _tracker(pol, backend, False).run(k, v, P)
    )(jax.random.key(1), video)
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"], np.float64),
        np.asarray(oc.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(of.ess, np.float64), np.asarray(oc.ess, np.float64)
    )
    np.testing.assert_array_equal(
        np.asarray(of.log_z_inc, np.float64),
        np.asarray(oc.log_z_inc, np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(ff.log_weights, np.float64),
        np.asarray(fc.log_weights, np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(ff.particles["pos"], np.float64),
        np.asarray(fc.particles["pos"], np.float64),
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bank_fused_matches_composed_bitwise(video, backend):
    pol = get_policy("bf16")
    _, of = _tracker(pol, backend, None, slots=3).run(
        jax.random.key(1), video, P
    )
    _, oc = _tracker(pol, backend, False, slots=3).run(
        jax.random.key(1), video, P
    )
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"], np.float64),
        np.asarray(oc.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(of.ess, np.float64), np.asarray(oc.ess, np.float64)
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ragged_bank_fused_matches_composed_bitwise(video, backend):
    """Ragged (partial budgets) fused == composed, and the fused ragged
    bank keeps the mask invariants (inactive lanes at -inf)."""
    pol = get_policy("fp32")
    budgets = jnp.asarray([P, 64, 16], jnp.int32)
    ff, of = _tracker(pol, backend, None, slots=3).run(
        jax.random.key(1), video, P, n_active=budgets
    )
    fc, oc = _tracker(pol, backend, False, slots=3).run(
        jax.random.key(1), video, P, n_active=budgets
    )
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"]), np.asarray(oc.estimate["pos"])
    )
    np.testing.assert_array_equal(np.asarray(of.ess), np.asarray(oc.ess))
    np.testing.assert_array_equal(
        np.asarray(ff.log_weights), np.asarray(fc.log_weights)
    )
    lw = np.asarray(ff.log_weights)
    assert np.isneginf(lw[1, 64:]).all() and np.isneginf(lw[2, 16:]).all()


@pytest.mark.parametrize("resampler", ["stratified", "multinomial", "metropolis"])
def test_jnp_fused_reference_covers_every_resampler(video, resampler):
    """The jnp backend dispatches a fused reference for every registered
    resampler — and it is bitwise the composed chain."""
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)
    outs = {}
    for fused in (None, False):
        flt = ParticleFilter(
            spec,
            FilterConfig(policy=pol, resampler=resampler, fused_epilogue=fused),
        )
        assert (fused is None) == (flt._fused is not None)
        _, outs[fused] = flt.run(jax.random.key(1), video, P)
    np.testing.assert_array_equal(
        np.asarray(outs[None].estimate["pos"]),
        np.asarray(outs[False].estimate["pos"]),
    )
    np.testing.assert_array_equal(
        np.asarray(outs[None].ess), np.asarray(outs[False].ess)
    )


def test_fused_epilogue_true_requires_kernel():
    """fused_epilogue=True validates at construction: pallas registers a
    fused kernel for systematic only."""
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend="pallas")
    spec = make_tracker_spec(cfg, pol)
    fc = FilterConfig(
        policy=pol,
        backend="pallas",
        resampler="stratified",
        fused_epilogue=True,
    )
    with pytest.raises(ValueError, match="fused"):
        ParticleFilter(spec, fc)
    with pytest.raises(ValueError, match="fused"):
        FilterBank(spec, fc, num_slots=2)
    # systematic has the kernel: construction succeeds and resolves it
    flt = ParticleFilter(spec, fc.with_(resampler="systematic"))
    assert flt._fused is not None


def test_fused_epilogue_true_meshed_validation():
    """On a meshed bank, fused_epilogue=True requires the local scheme's
    shard-local finalize; the exact scheme (all-gathered CDF) and backends
    without the kernel must raise instead of silently running composed."""
    pol = get_policy("fp32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec_p = make_tracker_spec(
        TrackerConfig(num_particles=P, height=H, width=W, backend="pallas"),
        pol,
    )
    with pytest.raises(ValueError, match="exact scheme has no fused"):
        FilterBank(
            spec_p,
            FilterConfig(
                policy=pol, backend="pallas", mesh=mesh, scheme="exact",
                fused_epilogue=True,
            ),
            num_slots=1,
        )
    with pytest.raises(ValueError, match="fused_finalize"):
        FilterBank(
            spec_p,
            FilterConfig(
                policy=pol, backend="jnp", mesh=mesh, scheme="local",
                fused_epilogue=True,
            ),
            num_slots=1,
        )
    # pallas + local + systematic has the finalize kernel: constructs,
    # and ragged init accepts (the masked finalize exists too)
    bank = FilterBank(
        spec_p,
        FilterConfig(
            policy=pol, backend="pallas", mesh=mesh, scheme="local",
            fused_epilogue=True,
        ),
        num_slots=1,
    )
    bank.init(jax.random.key(0), P, n_active=jnp.full((1,), P, jnp.int32))

    # the meshed *single* filter has no fused form at all
    with pytest.raises(ValueError, match="meshed ParticleFilter"):
        ParticleFilter(
            make_tracker_spec(
                TrackerConfig(num_particles=P, height=H, width=W), pol
            ),
            FilterConfig(
                policy=pol, mesh=jax.make_mesh((1,), ("data",)),
                fused_epilogue=True,
            ),
        )

    # a backend with the banked finalize but no masked finalize must
    # refuse a *ragged* meshed bank instead of silently running composed
    import dataclasses

    from repro.core.engine import BACKENDS, get_backend

    BACKENDS["_test_nomaskfin"] = dataclasses.replace(
        get_backend("pallas"), name="_test_nomaskfin", fused_finalize_masked={}
    )
    try:
        bank = FilterBank(
            spec_p,
            FilterConfig(
                policy=pol, backend="_test_nomaskfin", mesh=mesh,
                scheme="local", fused_epilogue=True,
            ),
            num_slots=1,
        )
        with pytest.raises(ValueError, match="masked fused finalize"):
            bank.init(
                jax.random.key(0), P, n_active=jnp.full((1,), P, jnp.int32)
            )
    finally:
        del BACKENDS["_test_nomaskfin"]


def test_naive_policy_never_fuses(video):
    """stable_weighting=False policies skip the fused path (the naive
    overflow demonstration must stay the naive chain)."""
    pol = get_policy("fp16_naive")
    flt = _tracker(pol, "jnp", None)
    assert flt._fused is None
    bank = _tracker(pol, "jnp", None, slots=2)
    assert bank._fused_banked is None


if given is not None:

    @given(st.integers(1, 1500), st.sampled_from(DTYPES))
    @settings(max_examples=20, deadline=None)
    def test_fused_epilogue_prefix_property(n, dt):
        """∀ n: masked fused row (junk tail) ≡ the composed masked chain
        AND the unmasked width-n fused kernel, bitwise."""
        width = 1536
        x = _junk_rows(jax.random.key(n), 1, width, [n], dt)
        n_act = jnp.asarray([n], jnp.int32)
        key = jax.random.key(n + 1)[None]
        fused = epi_ops.fused_epilogue_masked(key, x, n_act)
        w, m, lse, sw, sw2 = lse_ops.normalize_weights_stats_masked(x, n_act)
        anc = res_ops.systematic_resample_masked(key, w, n_act)
        for got, want in zip(fused, (w, anc, lse, m, sw, sw2)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        single = epi_ops.fused_epilogue(key[0], x[0, :n])
        np.testing.assert_array_equal(
            np.asarray(fused[0][0, :n], np.float32),
            np.asarray(single[0], np.float32),
        )
        np.testing.assert_array_equal(
            np.asarray(fused[1][0, :n]), np.asarray(single[1])
        )


# ---------------------------------------------------------------------------
# Meshed: the shard-local fused finalize (local RNA scheme)

from tests._mp import run_with_devices  # noqa: E402

MESHED_FUSED = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

video, _ = generate_video(jax.random.key(0),
                          VideoConfig(num_frames=5, height=64, width=64))
pol = get_policy("fp32")
spec = make_tracker_spec(
    TrackerConfig(num_particles=512, height=64, width=64,
                  backend="pallas"), pol)
mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)

def run(fused, n_active=None):
    bank = FilterBank(spec, FilterConfig(policy=pol, backend="pallas",
                                         mesh=mesh, scheme="local",
                                         fused_epilogue=fused), num_slots=2)
    state = bank.init(jax.random.key(1), 512, n_active=n_active)
    outs = []
    for t in range(5):
        ks = jax.random.split(jax.random.key(100 + t), 2)
        state, out = bank.jit_step_shared(state, video[t], ks)
        outs.append(out)
    return state, outs

# fused finalize vs composed: carried state, estimates, and evidence are
# bitwise; ESS is allclose only (XLA refuses the exp into a different
# fusion for the composed ESS reduction, a 1-ulp wobble).
sf, of = run(None)
sc, oc = run(False)
for a, b in zip(of, oc):
    np.testing.assert_array_equal(np.asarray(a.estimate["pos"]),
                                  np.asarray(b.estimate["pos"]))
    np.testing.assert_array_equal(np.asarray(a.log_z_inc),
                                  np.asarray(b.log_z_inc))
    np.testing.assert_allclose(np.asarray(a.ess), np.asarray(b.ess),
                               rtol=1e-6)
np.testing.assert_array_equal(np.asarray(sf.log_weights),
                              np.asarray(sc.log_weights))
np.testing.assert_array_equal(np.asarray(sf.particles["pos"]),
                              np.asarray(sc.particles["pos"]))

# full-width ragged fused == dense fused, bitwise (incl. ESS: same graph)
sr, orr = run(None, n_active=jnp.full((2,), 512, jnp.int32))
np.testing.assert_array_equal(np.asarray(sf.log_weights),
                              np.asarray(sr.log_weights))
np.testing.assert_array_equal(np.asarray(sf.particles["pos"]),
                              np.asarray(sr.particles["pos"]))
for a, b in zip(of, orr):
    np.testing.assert_array_equal(np.asarray(a.ess), np.asarray(b.ess))

# partial budgets: mask invariants hold under the fused finalize
sp, op = run(None, n_active=jnp.asarray([512, 100], jnp.int32))
lw = np.asarray(sp.log_weights)
assert np.isneginf(lw[1, 100:]).all()
assert np.isfinite(np.asarray(op[-1].estimate["pos"])).all()
print("meshed fused finalize ok")
"""


def test_meshed_local_fused_finalize_matches_composed():
    """The meshed local-RNA fused finalize path == the composed shard-local
    chain on 8 forced devices (state/estimates/evidence bitwise), with the
    ragged mask invariants preserved."""
    out = run_with_devices(MESHED_FUSED, devices=8, timeout=600)
    assert "meshed fused finalize ok" in out
