"""Fused full-step equivalence: likelihood → weights in one pass.

The acceptance spine of the fused step kernel: with the same keys, the
single streaming pass (intensity likelihood → prior add → weight
epilogue, log-weights living only in VMEM) must reproduce the composed
``intensity_loglik → fused_epilogue`` chain bit for bit — per float
policy, dense / banked / ragged (including NaN/Inf-poisoned inactive
patch lanes), at any likelihood-chunk height, at the kernel level and
through the engine on both backends, plus the meshed local-scheme
shard-local head.  ``roofline --step``'s traffic model rides along.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need the dev extra; the rest run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.core import FilterBank, FilterConfig, ParticleFilter, get_policy
from repro.core.likelihood import IntensityModel
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.kernels.epilogue import ops as epi_ops
from repro.kernels.likelihood import ops as lik_ops
from repro.kernels.step import ops as step_ops

POLICIES = ["fp32", "bf16", "fp16", "fp16_mixed"]
FRAMES, H, W, P = 8, 64, 64, 256
MODEL = IntensityModel(radius=4)
OUT_NAMES = ["w", "anc", "lse", "m", "sw", "sw2"]


def _patches(key, nbank, p, lo=90.0, hi=240.0):
    return jax.random.uniform(
        key, (nbank, p, MODEL.num_points), jnp.float32, lo, hi
    )


def _composed(keys, patches, prior, pol):
    """The engine's best pre-fusion path: likelihood kernel → prior add →
    fused epilogue (the chain the step kernel claims to reproduce)."""
    cdt = pol.compute_dtype
    ll = jax.vmap(lambda p: lik_ops.intensity_loglik(p, MODEL, pol))(patches)
    log_w = prior[:, None] + ll.astype(cdt)
    return epi_ops.fused_epilogue_batched(keys, log_w)


# ---------------------------------------------------------------------------
# Kernel level


@pytest.mark.parametrize("pname", POLICIES)
@pytest.mark.parametrize("nbank,n", [(1, 1000), (3, 517)])
def test_fused_step_matches_composed_chain_bitwise(pname, nbank, n):
    """Fused step kernel == likelihood kernel + prior add + fused epilogue,
    every output, bit for bit, with the same keys."""
    pol = get_policy(pname)
    cdt = pol.compute_dtype
    keys = jax.random.split(jax.random.key(nbank * n), nbank)
    patches = _patches(jax.random.key(7), nbank, n)
    prior = jnp.full((nbank,), -float(np.log(n)), cdt)
    ref = _composed(keys, patches, prior, pol)
    got = step_ops.fused_step_batched(keys, patches, MODEL, prior, pol)
    assert got[0].dtype == cdt and got[1].dtype == jnp.int32
    for name, a, b in zip(OUT_NAMES, got, ref):
        np.testing.assert_array_equal(
            np.asarray(a, np.float64),
            np.asarray(b, np.float64),
            err_msg=f"{pname}: {name}",
        )


def test_fused_step_single_matches_batched_row():
    patches = _patches(jax.random.key(3), 3, 700)
    keys = jax.random.split(jax.random.key(1), 3)
    pol = get_policy("fp32")
    prior = jnp.full((3,), -float(np.log(700)), jnp.float32)
    batched = step_ops.fused_step_batched(keys, patches, MODEL, prior, pol)
    for i in range(3):
        single = step_ops.fused_step(keys[i], patches[i], MODEL, prior[i], pol)
        for name, b, s in zip(OUT_NAMES, batched, single):
            np.testing.assert_array_equal(
                np.asarray(b[i], np.float64),
                np.asarray(s, np.float64),
                err_msg=f"row {i}: {name}",
            )


@pytest.mark.parametrize("pname", ["fp32", "bf16"])
def test_fused_step_block_p_invariance(pname):
    """``block_p`` is a pure performance knob: the per-row likelihood sum
    folds through the fixed ``pairwise_sum`` tree, so every legal chunk
    height gives bit-identical outputs — including at 16-bit accumulation
    (the regression guard for raising ``DEFAULT_BLOCK_P``)."""
    pol = get_policy(pname)
    keys = jax.random.split(jax.random.key(11), 2)
    patches = _patches(jax.random.key(13), 2, 900)
    prior = jnp.full((2,), -float(np.log(900)), pol.compute_dtype)
    base = step_ops.fused_step_batched(
        keys, patches, MODEL, prior, pol, block_p=step_ops.DEFAULT_BLOCK_P
    )
    for block_p in (128, 512, 8192):
        got = step_ops.fused_step_batched(
            keys, patches, MODEL, prior, pol, block_p=block_p
        )
        for name, a, b in zip(OUT_NAMES, got, base):
            np.testing.assert_array_equal(
                np.asarray(a, np.float64),
                np.asarray(b, np.float64),
                err_msg=f"{pname} block_p={block_p}: {name}",
            )


def _junk_tails(patches, counts):
    """Poison inactive rows with NaN/Inf/huge patch values."""
    x = np.array(patches)
    junk = [3e4, float("nan"), float("inf"), float("-inf")]
    for i, n in enumerate(counts):
        for j in range(n, x.shape[1]):
            x[i, j, :] = junk[(i + j) % len(junk)]
    return jnp.asarray(x)


@pytest.mark.parametrize("pname", POLICIES)
def test_fused_step_masked_matches_unmasked_prefix_bitwise(pname):
    """Masked fused step (junk inactive patch rows, incl. NaN/Inf) == the
    unmasked kernel on the width-n prefix; inactive weights exactly 0."""
    pol = get_policy(pname)
    cdt = pol.compute_dtype
    counts = [700, 400, 1]
    keys = jax.random.split(jax.random.key(5), len(counts))
    patches = _junk_tails(_patches(jax.random.key(6), len(counts), 700), counts)
    n_act = jnp.asarray(counts, jnp.int32)
    log_uni = (-jnp.log(n_act.astype(jnp.float32))).astype(cdt)
    wm, ancm, lsem, mm, swm, sw2m = step_ops.fused_step_masked(
        keys, patches, MODEL, log_uni, pol, n_act
    )
    for i, n in enumerate(counts):
        wi, anci, lsei, mi, swi, sw2i = step_ops.fused_step(
            keys[i], patches[i, :n], MODEL, log_uni[i], pol
        )
        np.testing.assert_array_equal(
            np.asarray(wm[i, :n], np.float32),
            np.asarray(wi, np.float32),
            err_msg=f"{pname} n={n}: w",
        )
        np.testing.assert_array_equal(np.asarray(ancm[i, :n]), np.asarray(anci))
        assert (np.asarray(ancm[i, :n]) < n).all()
        np.testing.assert_array_equal(float(lsem[i]), float(lsei))
        np.testing.assert_array_equal(float(mm[i]), float(mi))
        np.testing.assert_array_equal(float(swm[i]), float(swi))
        np.testing.assert_array_equal(float(sw2m[i]), float(sw2i))
        assert (np.asarray(wm[i, n:], np.float32) == 0.0).all()


def test_fused_step_masked_full_width_bitwise_dense():
    keys = jax.random.split(jax.random.key(9), 2)
    patches = _patches(jax.random.key(10), 2, 600)
    pol = get_policy("fp32")
    prior = jnp.full((2,), -float(np.log(600)), jnp.float32)
    full = jnp.full((2,), 600, jnp.int32)
    a = step_ops.fused_step_masked(keys, patches, MODEL, prior, pol, full)
    b = step_ops.fused_step_batched(keys, patches, MODEL, prior, pol)
    for name, u, v in zip(OUT_NAMES, a, b):
        np.testing.assert_array_equal(
            np.asarray(u), np.asarray(v), err_msg=name
        )


def test_fused_step_masked_counts_are_traced():
    """Changing the active counts must hit the jit cache — ragged banks
    resize every admission and cannot afford a retrace per count."""
    keys = jax.random.split(jax.random.key(15), 2)
    patches = _patches(jax.random.key(16), 2, 333)
    pol = get_policy("fp32")
    prior = jnp.full((2,), -float(np.log(333)), jnp.float32)
    step_ops.fused_step_masked(
        keys, patches, MODEL, prior, pol, jnp.asarray([333, 100], jnp.int32)
    )
    mid = step_ops.fused_step_masked._cache_size()
    step_ops.fused_step_masked(
        keys, patches, MODEL, prior, pol, jnp.asarray([17, 333], jnp.int32)
    )
    assert step_ops.fused_step_masked._cache_size() == mid


if given is not None:

    @given(st.integers(1, 1500), st.sampled_from(POLICIES))
    @settings(max_examples=20, deadline=None)
    def test_fused_step_prefix_property(n, pname):
        """∀ n: the masked fused step's active prefix (junk tail) ≡ the
        unmasked width-n fused step, bitwise, at every policy."""
        pol = get_policy(pname)
        cdt = pol.compute_dtype
        patches = _junk_tails(_patches(jax.random.key(n), 1, 1500), [n])
        n_act = jnp.asarray([n], jnp.int32)
        log_uni = (-jnp.log(n_act.astype(jnp.float32))).astype(cdt)
        keys = jax.random.key(n + 1)[None]
        masked = step_ops.fused_step_masked(
            keys, patches, MODEL, log_uni, pol, n_act
        )
        single = step_ops.fused_step(
            keys[0], patches[0, :n], MODEL, log_uni[0], pol
        )
        np.testing.assert_array_equal(
            np.asarray(masked[0][0, :n], np.float32),
            np.asarray(single[0], np.float32),
        )
        np.testing.assert_array_equal(
            np.asarray(masked[1][0, :n]), np.asarray(single[1])
        )
        for name, a, b in zip(OUT_NAMES[2:], masked[2:], single[2:]):
            np.testing.assert_array_equal(
                float(a[0]), float(b), err_msg=f"n={n} {pname}: {name}"
            )


# ---------------------------------------------------------------------------
# Engine level


@pytest.fixture(scope="module")
def video():
    return jax.random.uniform(
        jax.random.key(0), (FRAMES, H, W), jnp.float32, 90.0, 240.0
    )


def _tracker(policy, backend, fused_step, thr=1.0, slots=None, **cfg_kw):
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend=backend)
    fc = FilterConfig(
        policy=policy,
        backend=backend,
        ess_threshold=thr,
        fused_step=fused_step,
        **cfg_kw,
    )
    if slots is None:
        return ParticleFilter(make_tracker_spec(cfg, policy), fc)
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])[:slots]
    spec = make_tracker_spec(cfg, policy, starts=starts)
    return FilterBank(spec, fc, num_slots=slots)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("pname", ["fp32", "fp16"])
def test_engine_fused_step_matches_composed_bitwise(video, pname, backend):
    """ParticleFilter with fused_step=True == the forced composed chain,
    every output and the carried state, bit for bit."""
    pol = get_policy(pname)
    flt = _tracker(pol, backend, True)
    assert flt._fused_step is not None
    ff, of = jax.jit(lambda k, v: flt.run(k, v, P))(jax.random.key(1), video)
    fc, oc = jax.jit(
        lambda k, v: _tracker(pol, backend, False).run(k, v, P)
    )(jax.random.key(1), video)
    for attr in ("ess", "log_z_inc"):
        np.testing.assert_array_equal(
            np.asarray(getattr(of, attr), np.float64),
            np.asarray(getattr(oc, attr), np.float64),
            err_msg=attr,
        )
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"], np.float64),
        np.asarray(oc.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(ff.particles["pos"], np.float64),
        np.asarray(fc.particles["pos"], np.float64),
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bank_fused_step_matches_composed_bitwise(video, backend):
    pol = get_policy("bf16")
    bank = _tracker(pol, backend, True, slots=3)
    assert bank._fused_step_banked is not None
    ff, of = bank.run(jax.random.key(1), video, P)
    fc, oc = _tracker(pol, backend, False, slots=3).run(
        jax.random.key(1), video, P
    )
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"], np.float64),
        np.asarray(oc.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(ff.log_weights, np.float64),
        np.asarray(fc.log_weights, np.float64),
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ragged_bank_fused_step_matches_composed_bitwise(video, backend):
    pol = get_policy("fp32")
    budgets = jnp.asarray([P, 64, 16], jnp.int32)
    bank = _tracker(pol, backend, True, slots=3)
    assert bank._fused_step_masked is not None
    ff, of = bank.run(jax.random.key(1), video, P, n_active=budgets)
    fc, oc = _tracker(pol, backend, False, slots=3).run(
        jax.random.key(1), video, P, n_active=budgets
    )
    np.testing.assert_array_equal(
        np.asarray(of.estimate["pos"]), np.asarray(oc.estimate["pos"])
    )
    np.testing.assert_array_equal(np.asarray(of.ess), np.asarray(oc.ess))
    np.testing.assert_array_equal(
        np.asarray(ff.log_weights), np.asarray(fc.log_weights)
    )
    lw = np.asarray(ff.log_weights)
    assert np.isneginf(lw[1, 64:]).all() and np.isneginf(lw[2, 16:]).all()


def test_fused_step_auto_gates(video):
    """Auto (None) only engages on the static always-resample path with a
    stable-weighting policy and a spec opt-in."""
    pol = get_policy("fp32")
    assert _tracker(pol, "pallas", None)._fused_step is not None
    # adaptive resampling: the prior carry is no longer constant-uniform
    assert _tracker(pol, "pallas", None, thr=0.5)._fused_step is None
    # naive weighting never fuses
    naive = get_policy("fp16_naive")
    assert _tracker(naive, "jnp", None)._fused_step is None
    assert _tracker(naive, "jnp", None, slots=2)._fused_step_banked is None


def test_fused_step_true_validation():
    """fused_step=True raises wherever the fused form cannot apply instead
    of silently running composed."""
    import dataclasses

    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend="pallas")
    spec = make_tracker_spec(cfg, pol)

    # the spec must opt in (an opaque loglik cannot be fused)
    bare = dataclasses.replace(spec, step_fusion=None)
    with pytest.raises(ValueError, match="opt in"):
        ParticleFilter(
            bare, FilterConfig(policy=pol, backend="pallas", fused_step=True)
        )

    # adaptive resampling contradicts the constant-uniform prior fold
    with pytest.raises(ValueError, match="ess_threshold"):
        ParticleFilter(
            spec,
            FilterConfig(
                policy=pol, backend="pallas", ess_threshold=0.5,
                fused_step=True,
            ),
        )

    # pallas registers a fused step for systematic only
    with pytest.raises(ValueError, match="no fused step"):
        ParticleFilter(
            spec,
            FilterConfig(
                policy=pol, backend="pallas", resampler="stratified",
                fused_step=True,
            ),
        )

    # the meshed single filter has no fused form at all
    with pytest.raises(ValueError, match="meshed"):
        ParticleFilter(
            spec,
            FilterConfig(
                policy=pol, backend="pallas",
                mesh=jax.make_mesh((1,), ("data",)), fused_step=True,
            ),
        )

    # meshed bank: only the local scheme has a fused head...
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="scheme='local'"):
        FilterBank(
            spec,
            FilterConfig(
                policy=pol, backend="pallas", mesh=mesh, scheme="exact",
                fused_step=True,
            ),
            num_slots=1,
        )
    # ...and disabling its fused-finalize tail is contradictory
    with pytest.raises(ValueError, match="contradictory"):
        FilterBank(
            spec,
            FilterConfig(
                policy=pol, backend="pallas", mesh=mesh, scheme="local",
                fused_step=True, fused_epilogue=False,
            ),
            num_slots=1,
        )
    # the happy meshed path constructs
    FilterBank(
        spec,
        FilterConfig(
            policy=pol, backend="pallas", mesh=mesh, scheme="local",
            fused_step=True,
        ),
        num_slots=1,
    )


# ---------------------------------------------------------------------------
# Meshed: the shard-local fused-step head (local RNA scheme)

from tests._mp import run_with_devices  # noqa: E402

MESHED_STEP = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec

video = jax.random.uniform(jax.random.key(0), (4, 64, 64), jnp.float32,
                           90.0, 240.0)
pol = get_policy("fp32")
spec = make_tracker_spec(
    TrackerConfig(num_particles=512, height=64, width=64,
                  backend="pallas"), pol,
    starts=jnp.asarray([[16.0, 16.0], [48.0, 48.0]]))
mesh = jax.make_mesh((2, 2), ("data", "model"))

def run(fused, n_active=None):
    bank = FilterBank(spec, FilterConfig(policy=pol, backend="pallas",
                                         mesh=mesh, scheme="local",
                                         fused_step=fused), num_slots=2)
    return bank.run(jax.random.key(7), video, 512, n_active=n_active)

sf, of = run(True)
sc, oc = run(False)
np.testing.assert_array_equal(np.asarray(of.estimate["pos"]),
                              np.asarray(oc.estimate["pos"]))
np.testing.assert_array_equal(np.asarray(of.ess), np.asarray(oc.ess))
np.testing.assert_array_equal(np.asarray(sf.log_weights),
                              np.asarray(sc.log_weights))
np.testing.assert_array_equal(np.asarray(sf.particles["pos"]),
                              np.asarray(sc.particles["pos"]))

n_act = jnp.asarray([512, 256], jnp.int32)
srf, orf = run(True, n_active=n_act)
src, orc = run(False, n_active=n_act)
np.testing.assert_array_equal(np.asarray(orf.estimate["pos"]),
                              np.asarray(orc.estimate["pos"]))
np.testing.assert_array_equal(np.asarray(orf.ess), np.asarray(orc.ess))
np.testing.assert_array_equal(np.asarray(srf.log_weights),
                              np.asarray(src.log_weights))
print("meshed fused step ok")
"""


def test_meshed_local_fused_step_matches_composed():
    """The meshed local-RNA fused-step head (likelihood + prior add +
    shard-local LSE stats in one pass, chained into the fused finalize)
    == the composed shard-local chain on 4 forced devices, dense and
    ragged, bitwise."""
    out = run_with_devices(MESHED_STEP, devices=4, timeout=600)
    assert "meshed fused step ok" in out


# ---------------------------------------------------------------------------
# Roofline traffic model


def test_roofline_step_traffic(tmp_path, monkeypatch):
    """``roofline --step``: the fused step strictly lowers bytes per
    particle-step for every policy, and measured speedups attach from
    BENCH_fig6.json when present."""
    from repro.launch import roofline

    monkeypatch.chdir(tmp_path)
    rows = roofline.step_rows()
    assert rows
    for r in rows:
        assert (
            r["bytes_per_particle_fused"] < r["bytes_per_particle_composed"]
        ), r["policy"]
        assert r["bytes_per_particle_composed"] < (
            r["bytes_per_particle_composed_pre"]
        ), r["policy"]
        assert r["measured_speedup"] is None
    with open("BENCH_fig6.json", "w") as f:
        json.dump(
            {
                "records": [
                    {
                        "policy": "fp32",
                        "particles": 1024,
                        "speedup_fused_vs_composed": 7.5,
                    }
                ]
            },
            f,
        )
    rows = roofline.step_rows(particles=1024)
    by = {r["policy"]: r for r in rows}
    assert by["fp32"]["measured_speedup"] == 7.5
    md = roofline.render_step_markdown(rows)
    assert "fp32" in md and "7.50x" in md
