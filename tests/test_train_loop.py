"""Training-loop semantics: descent, microbatch equivalence, loss scaling."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.precision import get_policy
from repro.data.tokens import BatchSpec, make_batch
from repro.models import model as M
from repro.optim import init_opt_state
from repro.train import TrainConfig, make_train_step

CFG = reduced_config(get_config("minitron-8b"))


def _run(policy_name, micro, steps=12, seed=42):
    pol = get_policy(policy_name)
    tcfg = TrainConfig(microbatches=micro, total_steps=50, warmup_steps=2)
    params = M.init_params(jax.random.key(1), CFG, jnp.float32)
    opt = init_opt_state(params, tcfg.opt)
    step_fn = jax.jit(make_train_step(CFG, pol, tcfg))
    spec = BatchSpec("train", 8, 64)
    losses = []
    for i in range(steps):
        batch = make_batch(CFG, spec, seed, i)
        params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    return params, losses


def test_loss_descends():
    _, losses = _run("bf16_mixed", micro=2, steps=15)
    assert losses[-1] < losses[0] - 0.3, losses


def test_microbatch_equivalence_fp32():
    """1 vs 4 microbatches: same summed-gradient semantics (fp32, modulo
    accumulation order)."""
    p1, l1 = _run("fp32", micro=1, steps=3)
    p4, l4 = _run("fp32", micro=4, steps=3)
    np.testing.assert_allclose(l1, l4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-3
        )


def test_fp16_loss_scaling_finite():
    """fp16_mixed scales the loss by 2^12; reported metrics are unscaled
    and finite, and training still descends."""
    _, losses = _run("fp16_mixed", micro=2, steps=10)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert losses[0] < 20.0  # unscaled (a scaled loss would be ~2.6e4)


def test_data_pipeline_determinism():
    spec = BatchSpec("train", 4, 32)
    b1 = make_batch(CFG, spec, 7, 3)
    b2 = make_batch(CFG, spec, 7, 3)
    b3 = make_batch(CFG, spec, 7, 4)
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"]), np.asarray(b2["tokens"])
    )
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < CFG.vocab_size
