"""Property tests for the resampling schemes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import resampling
from repro.core.precision import get_policy

POL = get_policy("fp32")


@st.composite
def weight_arrays(draw, max_len=128):
    n = draw(st.integers(4, max_len))
    ws = draw(
        st.lists(st.floats(1e-6, 1.0), min_size=n, max_size=n)
    )
    w = np.array(ws, np.float32)
    return w / w.sum()


@given(weight_arrays())
@settings(max_examples=50, deadline=None)
def test_systematic_counts_floor_ceil(w):
    """Systematic resampling guarantee: count_i in {floor(Nw_i), ceil(Nw_i)}."""
    n = w.shape[0]
    anc = np.asarray(
        resampling.systematic(jax.random.key(3), jnp.asarray(w), POL)
    )
    counts = np.bincount(anc, minlength=n)
    expect = n * w
    assert (counts >= np.floor(expect) - 1e-6).all()
    assert (counts <= np.ceil(expect) + 1e-6).all()


@given(weight_arrays())
@settings(max_examples=30, deadline=None)
def test_ancestors_sorted_and_in_range(w):
    for scheme in ("systematic", "stratified", "multinomial"):
        fn = resampling.make_resampler(scheme)
        anc = np.asarray(fn(jax.random.key(5), jnp.asarray(w), POL))
        assert (np.diff(anc) >= 0).all(), scheme  # CDF inversion is monotone
        assert anc.min() >= 0 and anc.max() < w.shape[0], scheme


def test_multinomial_unbiased():
    """Mean counts over many keys ~ N*w."""
    w = jnp.asarray([0.5, 0.25, 0.125, 0.125], jnp.float32)
    n_rep = 300
    counts = np.zeros(4)
    for i in range(n_rep):
        anc = np.asarray(
            resampling.multinomial(jax.random.key(i), w, POL)
        )
        counts += np.bincount(anc, minlength=4)
    est = counts / (n_rep * 4)
    np.testing.assert_allclose(est, np.asarray(w), atol=0.03)


def test_degenerate_one_hot_weight():
    w = jnp.zeros((64,), jnp.float32).at[17].set(1.0)
    anc = np.asarray(resampling.systematic(jax.random.key(0), w, POL))
    assert (anc == 17).all()


def test_fp16_cdf_subnormal_regime():
    """The paper's resampling precision hazard, demonstrated: with 64k
    particles, uniform fp16 weights (1/65536) are *subnormal*; a pure-fp16
    CDF stalls once the running sum's ulp exceeds the increment (~0.06), so
    resampling degenerates.  The fp32-accum policy (our TPU default, free on
    the VPU) keeps it exact — the quantified argument for the fused
    kernels' fp32 carries."""
    n = 1 << 16
    w16 = jnp.full((n,), np.float16(1.0 / n), jnp.float16)

    # pure fp16 (paper-faithful): degenerate — a tiny subset of ancestors
    # hoards nearly all offspring
    anc_pure = np.asarray(
        resampling.systematic(jax.random.key(1), w16, get_policy("fp16"))
    )
    counts_pure = np.bincount(anc_pure, minlength=n)
    assert counts_pure.max() > 100  # catastrophically non-uniform

    # fp32 accumulation: near-uniform, as it should be
    anc_mixed = np.asarray(
        resampling.systematic(jax.random.key(1), w16, get_policy("fp16_mixed"))
    )
    counts_mixed = np.bincount(anc_mixed, minlength=n)
    assert counts_mixed.max() <= 2


def test_gather_ancestors_pytree():
    parts = {"pos": jnp.arange(12.0).reshape(6, 2), "tag": jnp.arange(6)}
    anc = jnp.asarray([0, 0, 5, 5, 2, 1], jnp.int32)
    out = resampling.gather_ancestors(parts, anc)
    assert out["pos"].shape == (6, 2)
    np.testing.assert_array_equal(np.asarray(out["tag"]), [0, 0, 5, 5, 2, 1])
