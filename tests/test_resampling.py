"""Property tests for the resampling schemes."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need the dev extra; the rest run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.core import resampling
from repro.core.precision import get_policy

POL = get_policy("fp32")


if given is not None:

    @st.composite
    def weight_arrays(draw, max_len=128):
        n = draw(st.integers(4, max_len))
        ws = draw(
            st.lists(st.floats(1e-6, 1.0), min_size=n, max_size=n)
        )
        w = np.array(ws, np.float32)
        return w / w.sum()

    @given(weight_arrays())
    @settings(max_examples=50, deadline=None)
    def test_systematic_counts_floor_ceil(w):
        """Systematic guarantee: count_i in {floor(Nw_i), ceil(Nw_i)}."""
        n = w.shape[0]
        anc = np.asarray(
            resampling.systematic(jax.random.key(3), jnp.asarray(w), POL)
        )
        counts = np.bincount(anc, minlength=n)
        expect = n * w
        assert (counts >= np.floor(expect) - 1e-6).all()
        assert (counts <= np.ceil(expect) + 1e-6).all()

    @given(weight_arrays())
    @settings(max_examples=30, deadline=None)
    def test_ancestors_sorted_and_in_range(w):
        for scheme in ("systematic", "stratified", "multinomial"):
            fn = resampling.make_resampler(scheme)
            anc = np.asarray(fn(jax.random.key(5), jnp.asarray(w), POL))
            assert (np.diff(anc) >= 0).all(), scheme  # monotone inversion
            assert anc.min() >= 0 and anc.max() < w.shape[0], scheme


def test_multinomial_unbiased():
    """Mean counts over many keys ~ N*w."""
    w = jnp.asarray([0.5, 0.25, 0.125, 0.125], jnp.float32)
    n_rep = 300
    counts = np.zeros(4)
    for i in range(n_rep):
        anc = np.asarray(
            resampling.multinomial(jax.random.key(i), w, POL)
        )
        counts += np.bincount(anc, minlength=4)
    est = counts / (n_rep * 4)
    np.testing.assert_allclose(est, np.asarray(w), atol=0.03)


def test_degenerate_one_hot_weight():
    w = jnp.zeros((64,), jnp.float32).at[17].set(1.0)
    anc = np.asarray(resampling.systematic(jax.random.key(0), w, POL))
    assert (anc == 17).all()


def test_fp16_cdf_subnormal_regime():
    """The paper's resampling precision hazard, demonstrated: with 64k
    particles, uniform fp16 weights (1/65536) are *subnormal*; a pure-fp16
    CDF stalls once the running sum's ulp exceeds the increment (~0.06), so
    resampling degenerates.  The fp32-accum policy (our TPU default, free on
    the VPU) keeps it exact — the quantified argument for the fused
    kernels' fp32 carries."""
    n = 1 << 16
    w16 = jnp.full((n,), np.float16(1.0 / n), jnp.float16)

    # pure fp16 (paper-faithful): degenerate — a tiny subset of ancestors
    # hoards nearly all offspring
    anc_pure = np.asarray(
        resampling.systematic(jax.random.key(1), w16, get_policy("fp16"))
    )
    counts_pure = np.bincount(anc_pure, minlength=n)
    assert counts_pure.max() > 100  # catastrophically non-uniform

    # fp32 accumulation: near-uniform, as it should be
    anc_mixed = np.asarray(
        resampling.systematic(jax.random.key(1), w16, get_policy("fp16_mixed"))
    )
    counts_mixed = np.bincount(anc_mixed, minlength=n)
    assert counts_mixed.max() <= 2


@functools.partial(jax.jit, static_argnames=("n", "iters"))
def _metropolis(key, w, *, n, iters=resampling.METROPOLIS_ITERS):
    return resampling.metropolis(key, w, POL, num_samples=n, iters=iters)


def test_metropolis_in_range_and_registered():
    w = jnp.asarray([0.25, 0.25, 0.25, 0.25], jnp.float32)
    anc = np.asarray(resampling.get_resampler("metropolis")(
        jax.random.key(0), w, POL
    ))
    assert anc.shape == (4,) and anc.dtype == np.int32
    assert anc.min() >= 0 and anc.max() < 4


def test_metropolis_degenerate_one_hot():
    """One-hot weights are the fixed-chain worst case: a chain only moves
    when it *proposes* the heavy index (accepting it for good), so coverage
    needs B >> n draws — Murray's B ~ log(eps)/log(1 - 1/n) bound, ~530
    for n=64, eps=1e-4.  At B=1024 every chain must have converged; at the
    default B=32 most chains are still stuck on their zero-weight start."""
    w = jnp.zeros((64,), jnp.float32).at[17].set(1.0)
    anc = np.asarray(_metropolis(jax.random.key(0), w, n=64, iters=1024))
    assert (anc == 17).all()
    anc_short = np.asarray(_metropolis(jax.random.key(0), w, n=64))
    assert (anc_short == 17).mean() < 0.9  # the knob matters


def test_metropolis_unbiased_vs_systematic():
    """Bias test against the systematic baseline: at the default chain
    length the mean offspring counts match N*w about as tightly as
    systematic's floor/ceil guarantee; at chain length 2 the truncation
    bias is an order of magnitude larger (the fixed-iteration trade-off
    Murray's scheme makes for being collective-free)."""
    w = jnp.asarray([0.5, 0.25, 0.125, 0.125], jnp.float32)
    n_out, reps = 256, 50
    counts = np.zeros(4)
    for i in range(reps):
        anc = np.asarray(_metropolis(jax.random.key(i), w, n=n_out))
        counts += np.bincount(anc, minlength=4)
    est = counts / (reps * n_out)
    np.testing.assert_allclose(est, np.asarray(w), atol=0.02)

    # systematic: single-draw counts already floor/ceil-exact
    anc_sys = np.asarray(
        resampling.systematic(jax.random.key(0), w, POL, n_out)
    )
    sys_err = np.abs(
        np.bincount(anc_sys, minlength=4) / n_out - np.asarray(w)
    ).max()
    assert sys_err <= 1.0 / n_out + 1e-6

    def chain_err(iters):
        tot = np.zeros(4)
        for i in range(10):
            anc = np.asarray(
                _metropolis(jax.random.key(100 + i), w, n=4096, iters=iters)
            )
            tot += np.bincount(anc, minlength=4)
        return np.abs(tot / tot.sum() - np.asarray(w)).max()

    short, converged = chain_err(2), chain_err(resampling.METROPOLIS_ITERS)
    assert converged < 0.01
    assert short > 5 * converged  # truncation bias is real and monotone


def test_metropolis_no_collectives_in_hlo():
    """The scheme's point: no cumsum/sort over the weights — the compiled
    step contains no reduce-window (prefix-sum) or sort ops."""
    w = jnp.asarray(np.full(128, 1 / 128, np.float32))
    hlo = jax.jit(
        lambda k, ww: resampling.metropolis(k, ww, POL)
    ).lower(jax.random.key(0), w).compile().as_text()
    assert "reduce-window" not in hlo and "sort(" not in hlo


def test_gather_ancestors_pytree():
    parts = {"pos": jnp.arange(12.0).reshape(6, 2), "tag": jnp.arange(6)}
    anc = jnp.asarray([0, 0, 5, 5, 2, 1], jnp.int32)
    out = resampling.gather_ancestors(parts, anc)
    assert out["pos"].shape == (6, 2)
    np.testing.assert_array_equal(np.asarray(out["tag"]), [0, 0, 5, 5, 2, 1])
