"""Launch-layer plumbing: cell building, dry-run compile, meter solve."""

import pytest

from tests._mp import run_with_devices


def test_build_cell_compiles_on_small_mesh():
    """One reduced train cell + one decode cell lower+compile end to end
    (8 host devices, (2,4) mesh) — the dryrun machinery in miniature."""
    out = run_with_devices(
        """
import jax, jax.numpy as jnp
from repro.launch.specs import build_cell
from repro.compat import cost_analysis
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
jax.set_mesh(mesh)
for arch, shape, kw in [
    ("minitron-8b", "train_4k", dict(train_micro=2, seq_override=64, batch_override=8)),
    ("minitron-8b", "decode_32k", dict(seq_override=128, batch_override=8)),
]:
    cell = build_cell(arch, shape, mesh,
                      cfg_overrides=dict(num_layers=2, d_model=128, num_heads=4,
                                         num_kv_heads=2, head_dim=32, d_ff=256,
                                         vocab_size=512),
                      **kw)
    compiled = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                       out_shardings=cell["out_shardings"],
                       donate_argnums=cell["donate"]).lower(*cell["args"]).compile()
    ca = cost_analysis(compiled)
    assert ca.get("flops", 0) > 0, (arch, shape)
    print(arch, shape, "ok", ca.get("flops"))
""",
        devices=8,
        timeout=560,
    )
    assert out.count("ok") == 2


def test_skip_policy():
    from repro.launch.specs import cell_skip_reason

    assert cell_skip_reason("command-r-35b", "long_500k")
    assert cell_skip_reason("hubert-xlarge", "decode_32k")
    assert cell_skip_reason("gemma3-27b", "long_500k") is None
    assert cell_skip_reason("rwkv6-7b", "long_500k") is None
    assert cell_skip_reason("zamba2-2.7b", "train_4k") is None


def test_meter_layer_points_cover_archs():
    from repro.configs import get_config, list_archs
    from repro.launch.meter import _layer_points

    for arch in list_archs():
        cfg = get_config(arch)
        ks, compose = _layer_points(cfg)
        # compose must reproduce an affine model exactly
        f = {k: 3.0 + 2.0 * k for k in ks}
        want = 3.0 + 2.0 * cfg.num_layers
        got = compose(f)
        assert abs(got - want) < 1e-6, (arch, got, want)
