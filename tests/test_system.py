"""End-to-end paper validation: the object tracker across precisions.

Mirrors the paper's section-5 verification: a synthetic bouncing-disk video,
tracked at every precision level; fp64 is the baseline, fp32 must match it
(the paper reports *exact* prediction agreement), half precisions must stay
close to ground truth, and the naive (unfixed) fp16 must blow up — the
failure the paper's algorithmic changes exist to prevent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import TrackerConfig, get_policy, make_tracker_filter
from repro.data.synthetic_video import VideoConfig, generate_video

FRAMES, H, W, P = 40, 128, 128, 512


def _run_tracker(key, video, cfg, pol):
    flt = make_tracker_filter(cfg, pol)
    final, outs = jax.jit(lambda k, v: flt.run(k, v, cfg.num_particles))(
        key, video
    )
    return outs.estimate["pos"], outs


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )


def _rmse(traj, truth):
    t = np.asarray(traj, np.float64)
    g = np.asarray(truth, np.float64)
    return float(np.sqrt(np.mean(np.sum((t - g) ** 2, -1))))


def _track(video, policy_name, backend="jnp"):
    pol = get_policy(policy_name)
    cfg = TrackerConfig(
        num_particles=P, height=H, width=W, backend=backend
    )
    return _run_tracker(jax.random.key(1), video[0], cfg, pol)


@pytest.mark.parametrize("policy", ["fp32", "fp16", "bf16", "bf16_mixed"])
def test_tracking_accuracy(video, policy):
    traj, outs = _track(video, policy)
    assert bool(jnp.isfinite(traj).all()), policy
    rmse = _rmse(traj, video[1])
    assert rmse < 3.0, (policy, rmse)  # sub-3px on a 128px frame


def test_fp32_matches_fp64(video):
    """Paper: single-precision predictions exactly match double.  Their
    methodology: identical fp64 RNG draws cast to the target dtype — we run
    both policies under x64 so they share the draw stream (see
    tracking.make_tracker_spec)."""
    with compat.enable_x64(True):
        video64 = generate_video(
            jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
        )
        cfg = TrackerConfig(num_particles=P, height=H, width=W)
        traj32, _ = _run_tracker(
            jax.random.key(1), video64[0], cfg, get_policy("fp32")
        )
        traj64, _ = _run_tracker(
            jax.random.key(1), video64[0], cfg, get_policy("fp64")
        )
    d = np.abs(np.asarray(traj32, np.float64) - np.asarray(traj64, np.float64))
    # Shared fp64 draws make the two filters agree to ~1e-5 px until a
    # resampling tie lands exactly on a CDF boundary that fp32 rounds the
    # other way (frame 14 with this seed); past that the (chaotic) ancestry
    # decorrelates while both remain equally accurate.  The paper reports
    # full-run agreement for its seed; we assert the verifiable version:
    # (a) pre-tie agreement at fp32 resolution,
    assert d[:10].max() < 1e-3, d[:10].max()
    # (b) statistical equivalence of accuracy after divergence.
    g = np.asarray(video64[1], np.float64)
    rmse32 = np.sqrt(np.mean(np.sum((np.asarray(traj32, np.float64) - g) ** 2, -1)))
    rmse64 = np.sqrt(np.mean(np.sum((np.asarray(traj64, np.float64) - g) ** 2, -1)))
    assert abs(rmse32 - rmse64) < 0.5, (rmse32, rmse64)


def test_naive_fp16_overflows(video):
    """The paper's motivating failure: un-fixed fp16 produces non-finite
    weights (likelihood sum > 65504, exp overflow)."""
    traj, outs = _track(video, "fp16_naive")
    assert not bool(jnp.isfinite(traj).all())


def test_pallas_backend_matches_jnp(video):
    tj, _ = _track(video, "fp16", backend="jnp")
    tp, _ = _track(video, "fp16", backend="pallas")
    # same algorithm, fused kernels carry fp32 accumulators -> close, and
    # both track (identical ancestry is not required)
    assert _rmse(tp, video[1]) < 3.0
    assert _rmse(tj, video[1]) < 3.0


def test_half_accuracy_close_to_double(video):
    """Paper conclusion: 'relatively small loss of accuracy'."""
    t16, _ = _track(video, "fp16")
    rmse16 = _rmse(t16, video[1])
    t32, _ = _track(video, "fp32")
    rmse32 = _rmse(t32, video[1])
    assert rmse16 < rmse32 + 2.0  # within 2px of the fp32 tracker
