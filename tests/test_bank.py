"""FilterBank semantics: B=1 bit-identity, slot lifecycle, batched kernels,
multi-object tracking, and the continuous-batching serving scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FilterBank,
    FilterConfig,
    ParticleFilter,
    SMCSpec,
    get_policy,
)
from repro.core.tracking import (
    TrackerConfig,
    make_multi_tracker_filter,
    make_tracker_spec,
)
from repro.data.synthetic_video import VideoConfig, generate_video

FRAMES, H, W, P = 10, 64, 64, 256


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )[0]


def _bank_and_filter(policy, ess_threshold=1.0, backend="jnp"):
    cfg = TrackerConfig(num_particles=P, height=H, width=W, backend=backend)
    spec = make_tracker_spec(cfg, policy)
    fc = FilterConfig(
        policy=policy, backend=backend, ess_threshold=ess_threshold
    )
    return FilterBank(spec, fc, num_slots=1), ParticleFilter(spec, fc)


# Every registered policy: the paper's three precisions, the TPU mixed
# pair, the naive (stability fixes off) halves, and the fp8-weight serving
# policy.  fp64 needs x64 and gets its own test below.
@pytest.mark.parametrize(
    "pname",
    [
        "fp32",
        "bf16",
        "fp16",
        "bf16_mixed",
        "fp16_mixed",
        "fp16_naive",
        "bf16_naive",
        "bf16_w8",
    ],
)
def test_bank1_bit_identical_to_particle_filter(video, pname):
    """FilterBank(B=1).run == ParticleFilter.run, bit for bit, per policy."""
    pol = get_policy(pname)
    bank, flt = _bank_and_filter(pol)
    final_f, outs_f = jax.jit(lambda k, v: flt.run(k, v, P))(
        jax.random.key(1), video
    )
    final_b, outs_b = jax.jit(lambda k, v: bank.run(k, v, P))(
        jax.random.key(1), video
    )
    np.testing.assert_array_equal(
        np.asarray(outs_f.estimate["pos"], np.float64),
        np.asarray(outs_b.estimate["pos"][:, 0], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(final_f.log_weights, np.float64),
        np.asarray(final_b.log_weights[0], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(outs_f.ess, np.float64),
        np.asarray(outs_b.ess[:, 0], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(final_f.particles["pos"], np.float64),
        np.asarray(final_b.particles["pos"][0], np.float64),
    )


def test_bank1_bit_identical_fp64(video):
    """The remaining registered policy, under x64."""
    from repro import compat

    with compat.enable_x64(True):
        pol = get_policy("fp64")
        bank, flt = _bank_and_filter(pol)
        _, outs_f = flt.run(jax.random.key(1), video, P)
        _, outs_b = bank.run(jax.random.key(1), video, P)
        np.testing.assert_array_equal(
            np.asarray(outs_f.estimate["pos"]),
            np.asarray(outs_b.estimate["pos"][:, 0]),
        )


def test_bank1_bit_identical_adaptive_threshold(video):
    """The per-slot where-select path == ParticleFilter's lax.cond path."""
    pol = get_policy("fp32")
    bank, flt = _bank_and_filter(pol, ess_threshold=0.5)
    _, outs_f = jax.jit(lambda k, v: flt.run(k, v, P))(
        jax.random.key(1), video
    )
    _, outs_b = jax.jit(lambda k, v: bank.run(k, v, P))(
        jax.random.key(1), video
    )
    np.testing.assert_array_equal(
        np.asarray(outs_f.estimate["pos"]),
        np.asarray(outs_b.estimate["pos"][:, 0]),
    )
    np.testing.assert_array_equal(
        np.asarray(outs_f.resampled), np.asarray(outs_b.resampled[:, 0])
    )


def test_bank_slots_independent_of_bank_size(video):
    """A slot's trajectory depends only on its own key, not on B."""
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)
    bank2 = FilterBank(spec, FilterConfig(policy=pol), num_slots=2)
    keys = jax.random.split(jax.random.key(5), 2)
    state2 = bank2.init_slots(keys, P)
    bank1 = FilterBank(spec, FilterConfig(policy=pol), num_slots=1)
    state1 = bank1.init_slots(keys[1:], P)
    for t in range(3):
        tk = jax.random.split(jax.random.fold_in(jax.random.key(7), t), 2)
        state2, _ = bank2.step(state2, video[t], tk, shared_obs=True)
        state1, _ = bank1.step(state1, video[t], tk[1:], shared_obs=True)
    np.testing.assert_array_equal(
        np.asarray(state2.particles["pos"][1]),
        np.asarray(state1.particles["pos"][0]),
    )
    np.testing.assert_array_equal(
        np.asarray(state2.log_weights[1]), np.asarray(state1.log_weights[0])
    )


def test_reset_slot_mid_stream(video):
    """reset_slot restarts exactly one slot (fresh cloud at its start, step
    0, uniform weights) and leaves every other slot bit-untouched, without
    recompiling across slot indices."""
    pol = get_policy("fp32")
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])
    bank = make_multi_tracker_filter(
        TrackerConfig(num_particles=P, height=H, width=W), pol, starts
    )
    state = bank.init(jax.random.key(1), P)
    for t in range(3):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 3)
        state, _ = bank.jit_step_shared(state, video[t], ks)
    before = jax.tree.map(np.asarray, state)

    state = bank.jit_init_slot(state, jnp.int32(1), jax.random.key(9))
    assert np.asarray(state.step).tolist() == [3, 0, 3]
    for keep in (0, 2):
        np.testing.assert_array_equal(
            before.particles["pos"][keep],
            np.asarray(state.particles["pos"][keep]),
        )
        np.testing.assert_array_equal(
            before.log_weights[keep], np.asarray(state.log_weights[keep])
        )
    # fresh slot: uniform weights, cloud redrawn around its start position
    np.testing.assert_array_equal(
        np.asarray(state.log_weights[1]),
        np.full((P,), -np.log(P), np.float32),
    )
    center = np.asarray(state.particles["pos"][1]).mean(0)
    np.testing.assert_allclose(center, [48.0, 48.0], atol=3.0)

    # traced slot index: a different slot reuses the same compiled fn
    n_before = bank.jit_init_slot._cache_size()
    state = bank.jit_init_slot(state, jnp.int32(0), jax.random.key(10))
    assert bank.jit_init_slot._cache_size() == n_before
    assert int(state.step[0]) == 0
    # and the bank keeps stepping after a reset
    ks = jax.random.split(jax.random.key(11), 3)
    state, out = bank.jit_step_shared(state, video[3], ks)
    assert bool(np.isfinite(np.asarray(out.estimate["pos"])).all())


# The trajectory tolerance derives from the policy's *compute* dtype (the
# grid weights are rounded to), not its accum dtype: whenever weights live
# on a 16-bit grid, a single fp32 ulp of backend-dependent summation-order
# difference (the Pallas online LSE's blockwise fold vs jnp's two-pass sum)
# can cross an fp16/bf16 rounding boundary, flip one resampling CDF tie,
# and steer the (chaotic) trajectories down different equally-valid paths —
# agreement to a few pixels is the contract.  fp32-weight policies match to
# sub-pixel.  That 16-bit weight noise never means 16-bit *accumulation*:
# the jaxpr auditor (``repro.analysis.jaxpr_audit``) proves every
# reduction/scan carry in these very step functions runs fp32 under
# fp16_mixed/bf16_mixed, so a loosened atol here cannot mask an accum
# regression.  (The likelihood itself sums through one shared pairwise
# tree on both backends — ``repro.kernels.common.pairwise_sum`` — which is
# what keeps even the acquisition slots this close.)
def _trajectory_atol(pol):
    return 1e-1 if jnp.dtype(pol.compute_dtype).itemsize >= 4 else 4.0


@pytest.mark.parametrize("pname", ["fp32", "bf16", "fp16_mixed"])
def test_bank_pallas_matches_jnp(video, pname):
    """Banked pallas kernel chain ~= banked jnp chain on a 3-slot tracker."""
    pol = get_policy(pname)
    atol = _trajectory_atol(pol)
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])
    est = {}
    for backend in ("jnp", "pallas"):
        cfg = TrackerConfig(
            num_particles=P, height=H, width=W, backend=backend
        )
        bank = make_multi_tracker_filter(cfg, pol, starts)
        _, outs = bank.run(jax.random.key(1), video, P)
        est[backend] = np.asarray(outs.estimate["pos"], np.float64)
        assert np.isfinite(est[backend]).all()
    np.testing.assert_allclose(est["pallas"], est["jnp"], atol=atol)


def test_multi_object_bank_tracks_two_targets():
    """Two objects in one composited stream: each slot locks to its own."""
    pol = get_policy("fp32")
    base = dict(num_frames=24, height=96, width=96)
    va, ta = generate_video(
        jax.random.key(0), VideoConfig(start=(20.0, 20.0), **base)
    )
    vb, tb = generate_video(
        jax.random.key(1), VideoConfig(start=(70.0, 60.0), **base)
    )
    video2 = jnp.maximum(va, vb)  # brighter-object composite
    starts = jnp.stack([ta[0], tb[0]])
    bank = make_multi_tracker_filter(
        TrackerConfig(num_particles=1024, height=96, width=96), pol, starts
    )
    _, outs = jax.jit(lambda k, v: bank.run(k, v, 1024))(
        jax.random.key(2), video2
    )
    est = np.asarray(outs.estimate["pos"], np.float64)  # (T, 2, 2)
    truth = np.stack([np.asarray(ta), np.asarray(tb)], axis=1)
    rmse = np.sqrt(((est - truth) ** 2).sum(-1).mean(0))
    assert (rmse < 6.0).all(), rmse


def test_bank_metropolis_resampler(video):
    """Murray's collective-free scheme drives a bank end to end."""
    pol = get_policy("fp32")
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0]])
    cfg = TrackerConfig(
        num_particles=P, height=H, width=W, resampler="metropolis"
    )
    bank = make_multi_tracker_filter(cfg, pol, starts)
    _, outs = bank.run(jax.random.key(1), video, P)
    est = np.asarray(outs.estimate["pos"])
    assert est.shape == (FRAMES, 2, 2) and np.isfinite(est).all()


def test_bank_mesh_validation():
    """Mesh × bank composition validates its axes up front: the bank needs
    both a slot axis and a particle axis on the mesh."""
    spec = make_tracker_spec(
        TrackerConfig(num_particles=P, height=H, width=W), get_policy("fp32")
    )
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="mesh has no axis 'model'"):
        FilterBank(spec, FilterConfig(mesh=mesh), num_slots=2)
    with pytest.raises(ValueError, match="bank_axis"):
        FilterBank(
            spec,
            FilterConfig(mesh=mesh, axis="data", bank_axis="x"),
            num_slots=2,
        )
    with pytest.raises(ValueError, match="num_slots"):
        FilterBank(spec, num_slots=0)


def test_meshed_bank_single_device_mesh(video):
    """A (1, 1) data×model mesh runs the full distributed bank path in
    process (shard_map over one device) and stays a working filter."""
    pol = get_policy("fp32")
    spec = make_tracker_spec(
        TrackerConfig(num_particles=P, height=H, width=W), pol
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bank = FilterBank(
        spec, FilterConfig(policy=pol, mesh=mesh, scheme="exact"), num_slots=2
    )
    state = bank.init(jax.random.key(1), P)
    for t in range(3):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 2)
        state, out = bank.jit_step_shared(state, video[t], ks)
    assert np.isfinite(np.asarray(out.estimate["pos"])).all()
    assert np.asarray(state.step).tolist() == [3, 3]
    # resets compose with the meshed bank
    state = bank.jit_init_slot(state, jnp.int32(0), jax.random.key(9))
    assert np.asarray(state.step).tolist() == [0, 3]


def test_continuous_batching_scheduler():
    """serve --smc in miniature: more requests than slots, staggered
    arrivals, every request served exactly once with its own budget."""
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import make_smc_decode_spec, run_continuous_batching
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"))
    pol = get_policy("fp32")
    steps = 6
    params = M.init_params(jax.random.key(1), cfg, pol.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )
    spec = make_smc_decode_spec(
        params, cfg, pol, decode, temperature=1.0, steps=steps
    )
    bank = FilterBank(
        spec, FilterConfig(policy=pol, ess_threshold=0.5), num_slots=3
    )
    stats = run_continuous_batching(
        bank,
        num_requests=5,
        max_steps=steps,
        particles=2,
        key=jax.random.key(0),
        arrival_every=1,
    )
    results = stats["results"]
    assert [r["id"] for r in results] == list(range(5))
    for r in results:
        assert 1 <= r["steps"] <= steps
        assert r["tokens"].shape == (r["steps"],)
        assert (r["tokens"] >= 0).all() and (r["tokens"] < cfg.vocab_size).all()
        # a slot serves one request at a time: latency == budget here
        assert r["finished_tick"] - r["admitted_tick"] == r["steps"]
    # with 5 requests on 3 slots some request must wait for a free slot
    assert stats["ticks"] >= max(r["steps"] for r in results)
    assert 0.0 < stats["occupancy"] <= 1.0
