"""Buffer-donation regressions: the donated jit entry points must reuse
the FilterState buffers in place (no per-tick state copy), verified three
ways — the compiled program's input/output aliasing (memory analysis),
the absence of "donated buffer was not usable" warnings, and the donated
input arrays being invalidated after the call — while producing exactly
the non-donated results.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FilterBank, FilterConfig, ParticleFilter, SMCSpec, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.data.synthetic_video import VideoConfig, generate_video

H = W = 64
P = 128
B = 3


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=4, height=H, width=W)
    )[0]


def _bank(**cfg):
    pol = get_policy("fp32")
    tcfg = TrackerConfig(num_particles=P, height=H, width=W)
    starts = jnp.asarray([[16.0, 16.0], [48.0, 48.0], [32.0, 32.0]])
    spec = make_tracker_spec(tcfg, pol, starts=starts)
    return FilterBank(spec, FilterConfig(policy=pol, **cfg), num_slots=B)


def _state_leaves(state):
    return [x for x in jax.tree.leaves(state) if hasattr(x, "is_deleted")]


def _assert_no_donation_warnings(records):
    donation_noise = [
        str(r.message) for r in records if "donat" in str(r.message).lower()
    ]
    assert not donation_noise, donation_noise


def test_bank_step_shared_donated_consumes_and_matches(video):
    bank = _bank()
    keys = jax.random.split(jax.random.key(2), B)
    ref_state = bank.init(jax.random.key(1), P)
    ref_out = bank.jit_step_shared(ref_state, video[0], keys)

    state = bank.init(jax.random.key(1), P)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        new_state, out = bank.jit_step_shared_donated(state, video[0], keys)
        jax.block_until_ready(new_state)
    _assert_no_donation_warnings(rec)
    # every input state buffer was handed to the computation
    assert all(x.is_deleted() for x in _state_leaves(state))
    # same results as the non-donated step, bit for bit
    np.testing.assert_array_equal(
        np.asarray(new_state.log_weights), np.asarray(ref_out[0].log_weights)
    )
    np.testing.assert_array_equal(
        np.asarray(out.estimate["pos"]), np.asarray(ref_out[1].estimate["pos"])
    )
    # and the donated chain keeps stepping
    state2, _ = bank.jit_step_shared_donated(new_state, video[1], keys)
    assert not any(x.is_deleted() for x in _state_leaves(state2))


def test_bank_step_donated_per_slot_obs(video):
    bank = _bank()
    keys = jax.random.split(jax.random.key(2), B)
    obs = jnp.stack([video[0]] * B)
    state = bank.init(jax.random.key(1), P)
    new_state, _ = bank.jit_step_donated(state, obs, keys)
    assert all(x.is_deleted() for x in _state_leaves(state))
    assert not any(x.is_deleted() for x in _state_leaves(new_state))


def test_init_slot_donated_rewrites_in_place(video):
    bank = _bank()
    state = bank.init(jax.random.key(1), P)
    # Snapshot from a twin init: np.asarray on the state itself would pin
    # the buffer with a zero-copy view and silently block its donation.
    before = np.asarray(bank.init(jax.random.key(1), P).particles["pos"])
    new_state = bank.jit_init_slot_donated(
        state, jnp.int32(1), jax.random.key(9)
    )
    assert all(x.is_deleted() for x in _state_leaves(state))
    after = np.asarray(new_state.particles["pos"])
    np.testing.assert_array_equal(after[0], before[0])  # other slots intact
    np.testing.assert_array_equal(after[2], before[2])
    assert not np.array_equal(after[1], before[1])  # slot 1 re-drawn


def test_particle_filter_step_donated(video):
    pol = get_policy("fp32")
    spec = make_tracker_spec(
        TrackerConfig(num_particles=P, height=H, width=W), pol
    )
    flt = ParticleFilter(spec, FilterConfig(policy=pol))
    ref = flt.jit_step(
        flt.init(jax.random.key(1), P), video[0], jax.random.key(3)
    )
    state = flt.init(jax.random.key(1), P)
    new_state, out = flt.jit_step_donated(state, video[0], jax.random.key(3))
    assert all(x.is_deleted() for x in _state_leaves(state))
    np.testing.assert_array_equal(
        np.asarray(new_state.log_weights), np.asarray(ref[0].log_weights)
    )
    np.testing.assert_array_equal(
        np.asarray(out.estimate["pos"]), np.asarray(ref[1].estimate["pos"])
    )


def test_donated_step_aliases_state_memory(video):
    """Compile-level proof: the donated step's executable aliases the
    state bytes input→output (memory_analysis), the plain step aliases
    nothing — i.e. a scheduler tick allocates no fresh state copy."""
    bank = _bank()
    keys = jax.random.split(jax.random.key(2), B)
    state = bank.init(jax.random.key(1), P)
    state_bytes = sum(
        x.size * x.dtype.itemsize for x in _state_leaves(state)
    )
    plain = bank.jit_step_shared.lower(state, video[0], keys).compile()
    donated = bank.jit_step_shared_donated.lower(
        state, video[0], keys
    ).compile()
    assert plain.memory_analysis().alias_size_in_bytes == 0
    # The particle and weight buffers (the O(B*P) state) must be aliased;
    # tiny leaves (step counters) may legitimately fold into constants.
    assert donated.memory_analysis().alias_size_in_bytes >= 0.9 * state_bytes


def test_ragged_init_slot_donated_traced_count():
    """Donation composes with recompile-free ragged admission."""
    bank = _bank()
    state = bank.init(
        jax.random.key(1), P, n_active=jnp.full((B,), P, jnp.int32)
    )
    st2 = bank.jit_init_slot_donated(
        state, jnp.int32(2), jax.random.key(4), jnp.int32(32)
    )
    assert all(x.is_deleted() for x in _state_leaves(state))
    assert np.asarray(st2.n_active).tolist() == [P, P, 32]
    assert np.isneginf(np.asarray(st2.log_weights)[2, 32:]).all()


def test_scheduler_sync_donated_ticks_deterministic():
    """The sync continuous-batching loop (which runs on the donated step
    and reset) still serves a reproducible schedule end to end."""
    from repro.launch.serve import run_continuous_batching

    steps = 4

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok,
            reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    spec = SMCSpec(init, transition, lambda p, o, s: p["reward"])

    def serve_once():
        bank = FilterBank(
            spec,
            FilterConfig(policy=get_policy("fp32"), ess_threshold=0.5),
            num_slots=2,
        )
        return run_continuous_batching(
            bank,
            num_requests=5,
            max_steps=steps,
            particles=(2, 8),
            key=jax.random.key(11),
        )

    a, b = serve_once(), serve_once()
    assert [r["id"] for r in a["results"]] == [0, 1, 2, 3, 4]
    assert a["padding_waste"] == b["padding_waste"]
    for ra, rb in zip(a["results"], b["results"]):
        assert ra["particles"] == rb["particles"]
        assert ra["finished_tick"] == rb["finished_tick"]
        np.testing.assert_array_equal(ra["tokens"], rb["tokens"])
