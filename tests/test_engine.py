"""ParticleFilter engine: registries, dispatch, ESS semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FilterConfig,
    ParticleFilter,
    SMCSpec,
    get_policy,
)
from repro.core import resampling
from repro.core.engine import get_backend
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.data.synthetic_video import VideoConfig, generate_video

FRAMES, H, W, P = 12, 64, 64, 256


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )[0]


def _gauss_spec():
    def init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}

    def transition(key, particles, step):
        noise = jax.random.normal(key, particles["x"].shape, jnp.float32)
        return {"x": particles["x"] + 0.1 + 0.5 * noise}

    def loglik(particles, obs, step):
        return -0.5 * jnp.square(particles["x"] - obs)

    return SMCSpec(init, transition, loglik)


@pytest.mark.parametrize("policy", ["fp32", "bf16", "fp16", "bf16_mixed"])
def test_run_bit_identical_under_jit(video, policy):
    """Engine run is deterministic and jit-transparent per policy — the
    equivalence the legacy pf_scan shims used to anchor."""
    pol = get_policy(policy)
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)

    flt = ParticleFilter(spec, FilterConfig(policy=pol))
    final_e, outs_e = jax.jit(lambda k, v: flt.run(k, v, P))(
        jax.random.key(1), video
    )
    final_l, outs_l = flt.run(jax.random.key(1), video, P)

    np.testing.assert_array_equal(
        np.asarray(outs_e.estimate["pos"], np.float64),
        np.asarray(outs_l.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(final_e.log_weights, np.float64),
        np.asarray(final_l.log_weights, np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(outs_e.ess, np.float64), np.asarray(outs_l.ess, np.float64)
    )


def test_legacy_shims_removed():
    """ROADMAP said drop the pf_* / track shims once nothing uses them —
    they must stay gone (reappearing names mean a bad merge)."""
    import repro.core as core
    import repro.core.filter as filt
    import repro.core.tracking as tracking

    for mod in (core, filt):
        for name in ("pf_init", "pf_step", "pf_scan"):
            assert not hasattr(mod, name), f"{mod.__name__}.{name} is back"
    assert not hasattr(tracking, "track")
    assert not hasattr(core, "track")


def test_unknown_backend_raises_with_options():
    with pytest.raises(KeyError, match=r"'jnp', 'pallas'"):
        ParticleFilter(_gauss_spec(), FilterConfig(backend="cuda"))
    with pytest.raises(KeyError, match="unknown filter backend 'cuda'"):
        get_backend("cuda")


def test_unknown_resampler_raises_with_options():
    with pytest.raises(
        KeyError, match=r"'multinomial', 'stratified', 'systematic'"
    ):
        ParticleFilter(_gauss_spec(), FilterConfig(resampler="residual"))


def test_unknown_policy_and_scheme_raise():
    with pytest.raises(KeyError, match="unknown precision policy"):
        ParticleFilter(_gauss_spec(), FilterConfig(policy="fp8_imaginary"))
    with pytest.raises(KeyError, match=r"'exact', 'local'"):
        ParticleFilter(
            _gauss_spec(), FilterConfig(mesh=object(), scheme="global")
        )


def test_registered_resampler_dispatches():
    calls = []

    @resampling.register_resampler("_test_echo")
    def _echo(key, weights, policy, num_samples=None):
        calls.append(weights.shape[0])
        return jnp.arange(weights.shape[0], dtype=jnp.int32)

    try:
        flt = ParticleFilter(
            _gauss_spec(), FilterConfig(resampler="_test_echo")
        )
        state = flt.init(jax.random.key(0), 32)
        flt.step(state, jnp.float32(0.0), jax.random.key(1))
        assert calls == [32]
    finally:
        del resampling.RESAMPLERS["_test_echo"]


def test_stream_matches_step_by_step():
    spec = _gauss_spec()
    flt = ParticleFilter(spec, FilterConfig(policy="fp32"))
    obs = jnp.cumsum(jnp.full((8,), 0.1))

    key = jax.random.key(3)
    streamed = [
        float(out.ess)
        for _, out in flt.stream(key, list(obs), 128, jit=False)
    ]
    # replay manually with the same fold_in key path
    k_init, k_run = jax.random.split(key)
    state = flt.init(k_init, 128)
    replayed = []
    for i in range(8):
        state, out = flt.step(state, obs[i], jax.random.fold_in(k_run, i))
        replayed.append(float(out.ess))
    assert streamed == replayed


def _fixed_weight_spec(target_w):
    """Loglik forces post-step normalized weights to ``target_w`` exactly
    (transition is identity, init weights are uniform so the loglik alone
    sets the weight profile)."""
    target_log_w = jnp.log(jnp.asarray(target_w, jnp.float32))

    def init(key, n):
        del key
        return {"x": jnp.zeros((n,), jnp.float32)}

    def transition(key, particles, step):
        return particles

    def loglik(particles, obs, step):
        return target_log_w

    return SMCSpec(init, transition, loglik)


def test_ess_threshold_exact_no_early_fire():
    """Regression for the ``+ 0.5`` fudge: at threshold=0.5, ESS in
    [0.5*P, 0.5*P + 0.5) must NOT trigger a resample (the old comparison
    ``ess < 0.5*P + 0.5`` fired early)."""
    P = 8
    # Two-level weights with ESS = 1/sum(w^2) = 4.2 in [4, 4.5).
    d = np.sqrt((1 / 4.2 - 1 / 8) / 8)
    w = np.full(8, 0.125)
    w[:4] += d
    w[4:] -= d
    ess = 1.0 / np.sum(w**2)
    assert 4.0 < ess < 4.5
    spec = _fixed_weight_spec(w)
    flt = ParticleFilter(spec, FilterConfig(ess_threshold=0.5))
    state = flt.init(jax.random.key(0), P)
    state, out = flt.step(state, jnp.float32(0.0), jax.random.key(1))
    np.testing.assert_allclose(float(out.ess), ess, rtol=1e-5)
    assert not bool(out.resampled)
    # unresampled: the weight profile persists in the carried log-weights
    np.testing.assert_allclose(
        np.exp(np.asarray(state.log_weights)), w, rtol=1e-5
    )
    # ESS strictly below the exact threshold *does* fire
    w_low = np.asarray([0.4, 0.4, 0.04, 0.04, 0.04, 0.04, 0.02, 0.02])
    assert 1.0 / np.sum(w_low**2) < 4.0
    flt_low = ParticleFilter(
        _fixed_weight_spec(w_low), FilterConfig(ess_threshold=0.5)
    )
    state = flt_low.init(jax.random.key(0), P)
    _, out = flt_low.step(state, jnp.float32(0.0), jax.random.key(1))
    assert bool(out.resampled)


def test_ess_threshold_one_always_resamples():
    """threshold >= 1.0 is the explicit always-resample gate, firing even
    at the ESS == P maximum (uniform weights, where a strict comparison
    against P would not)."""
    P = 16
    spec = _fixed_weight_spec(np.full(P, 1.0 / P))
    flt = ParticleFilter(spec, FilterConfig(ess_threshold=1.0))
    state = flt.init(jax.random.key(0), P)
    state, out = flt.step(state, jnp.float32(0.0), jax.random.key(1))
    np.testing.assert_allclose(float(out.ess), P, rtol=1e-6)
    assert bool(out.resampled)
    np.testing.assert_allclose(
        np.asarray(state.log_weights), np.full(P, -np.log(P)), rtol=1e-6
    )


def test_backend_pallas_close_to_jnp(video):
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)
    ref = None
    for backend in ["jnp", "pallas"]:
        flt = ParticleFilter(spec, FilterConfig(policy=pol, backend=backend))
        _, outs = flt.run(jax.random.key(1), video, P)
        est = np.asarray(outs.estimate["pos"], np.float64)
        assert np.isfinite(est).all()
        if ref is None:
            ref = est
        else:
            np.testing.assert_allclose(est, ref, atol=1e-2)
