"""ParticleFilter engine: legacy equivalence, registries, deprecation shims."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FilterConfig,
    ParticleFilter,
    SMCSpec,
    get_policy,
)
from repro.core import filter as legacy
from repro.core import resampling
from repro.core.engine import get_backend
from repro.core.tracking import TrackerConfig, make_tracker_filter, make_tracker_spec
from repro.data.synthetic_video import VideoConfig, generate_video

FRAMES, H, W, P = 12, 64, 64, 256


@pytest.fixture(scope="module")
def video():
    return generate_video(
        jax.random.key(0), VideoConfig(num_frames=FRAMES, height=H, width=W)
    )[0]


def _gauss_spec():
    def init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}

    def transition(key, particles, step):
        noise = jax.random.normal(key, particles["x"].shape, jnp.float32)
        return {"x": particles["x"] + 0.1 + 0.5 * noise}

    def loglik(particles, obs, step):
        return -0.5 * jnp.square(particles["x"] - obs)

    return SMCSpec(init, transition, loglik)


@pytest.mark.parametrize("policy", ["fp32", "bf16", "fp16", "bf16_mixed"])
def test_run_bit_identical_to_legacy_pf_scan(video, policy):
    """Engine run == legacy pf_scan, bit for bit, on the tracker workload."""
    pol = get_policy(policy)
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)

    flt = ParticleFilter(spec, FilterConfig(policy=pol))
    final_e, outs_e = jax.jit(lambda k, v: flt.run(k, v, P))(
        jax.random.key(1), video
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        final_l, outs_l = jax.jit(
            lambda k, v: legacy.pf_scan(spec, pol, k, v, P)
        )(jax.random.key(1), video)

    np.testing.assert_array_equal(
        np.asarray(outs_e.estimate["pos"], np.float64),
        np.asarray(outs_l.estimate["pos"], np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(final_e.log_weights, np.float64),
        np.asarray(final_l.log_weights, np.float64),
    )
    np.testing.assert_array_equal(
        np.asarray(outs_e.ess, np.float64), np.asarray(outs_l.ess, np.float64)
    )


def test_track_shim_matches_engine(video):
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    flt = make_tracker_filter(cfg, pol)
    _, outs = flt.run(jax.random.key(1), video, P)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.tracking import track

        traj, _ = track(jax.random.key(1), video, cfg, pol)
    np.testing.assert_array_equal(
        np.asarray(traj), np.asarray(outs.estimate["pos"])
    )


def test_unknown_backend_raises_with_options():
    with pytest.raises(KeyError, match=r"'jnp', 'pallas'"):
        ParticleFilter(_gauss_spec(), FilterConfig(backend="cuda"))
    with pytest.raises(KeyError, match="unknown filter backend 'cuda'"):
        get_backend("cuda")


def test_unknown_resampler_raises_with_options():
    with pytest.raises(
        KeyError, match=r"'multinomial', 'stratified', 'systematic'"
    ):
        ParticleFilter(_gauss_spec(), FilterConfig(resampler="residual"))


def test_unknown_policy_and_scheme_raise():
    with pytest.raises(KeyError, match="unknown precision policy"):
        ParticleFilter(_gauss_spec(), FilterConfig(policy="fp8_imaginary"))
    with pytest.raises(KeyError, match=r"'exact', 'local'"):
        ParticleFilter(
            _gauss_spec(), FilterConfig(mesh=object(), scheme="global")
        )


def test_registered_resampler_dispatches():
    calls = []

    @resampling.register_resampler("_test_echo")
    def _echo(key, weights, policy, num_samples=None):
        calls.append(weights.shape[0])
        return jnp.arange(weights.shape[0], dtype=jnp.int32)

    try:
        flt = ParticleFilter(
            _gauss_spec(), FilterConfig(resampler="_test_echo")
        )
        state = flt.init(jax.random.key(0), 32)
        flt.step(state, jnp.float32(0.0), jax.random.key(1))
        assert calls == [32]
    finally:
        del resampling.RESAMPLERS["_test_echo"]


def test_shims_warn_exactly_once_and_forward():
    spec = _gauss_spec()
    pol = get_policy("fp32")
    legacy._WARNED.clear()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        state1 = legacy.pf_init(spec, pol, jax.random.key(0), 64)
        state2 = legacy.pf_init(spec, pol, jax.random.key(0), 64)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "pf_init" in str(dep[0].message)

    # forwards correctly: shim output == engine output
    ref = ParticleFilter(spec, FilterConfig(policy=pol)).init(
        jax.random.key(0), 64
    )
    np.testing.assert_array_equal(
        np.asarray(state1.particles["x"]), np.asarray(ref.particles["x"])
    )
    np.testing.assert_array_equal(
        np.asarray(state2.log_weights), np.asarray(ref.log_weights)
    )

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy.pf_step(spec, pol, state1, jnp.float32(0.0), jax.random.key(1))
        legacy.pf_step(spec, pol, state1, jnp.float32(0.0), jax.random.key(1))
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "pf_step" in str(dep[0].message)


def test_stream_matches_step_by_step():
    spec = _gauss_spec()
    flt = ParticleFilter(spec, FilterConfig(policy="fp32"))
    obs = jnp.cumsum(jnp.full((8,), 0.1))

    key = jax.random.key(3)
    streamed = [
        float(out.ess)
        for _, out in flt.stream(key, list(obs), 128, jit=False)
    ]
    # replay manually with the same fold_in key path
    k_init, k_run = jax.random.split(key)
    state = flt.init(k_init, 128)
    replayed = []
    for i in range(8):
        state, out = flt.step(state, obs[i], jax.random.fold_in(k_run, i))
        replayed.append(float(out.ess))
    assert streamed == replayed


def test_backend_pallas_close_to_jnp(video):
    pol = get_policy("fp32")
    cfg = TrackerConfig(num_particles=P, height=H, width=W)
    spec = make_tracker_spec(cfg, pol)
    ref = None
    for backend in ["jnp", "pallas"]:
        flt = ParticleFilter(spec, FilterConfig(policy=pol, backend=backend))
        _, outs = flt.run(jax.random.key(1), video, P)
        est = np.asarray(outs.estimate["pos"], np.float64)
        assert np.isfinite(est).all()
        if ref is None:
            ref = est
        else:
            np.testing.assert_allclose(est, ref, atol=1e-2)
