"""Filter-level invariants and backend equivalence (engine API)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FilterConfig, ParticleFilter, SMCSpec, get_policy

POL = get_policy("fp32")


def _gauss_spec(target=3.0):
    """1-D Gaussian tracking problem with a drifting target."""

    def init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}

    def transition(key, particles, step):
        noise = jax.random.normal(key, particles["x"].shape, jnp.float32)
        return {"x": particles["x"] + 0.1 + 0.5 * noise}

    def loglik(particles, obs, step):
        return -0.5 * jnp.square(particles["x"] - obs)

    return SMCSpec(init, transition, loglik)


def _engine(spec, **kw):
    return ParticleFilter(spec, FilterConfig(policy=POL, **kw))


def test_init_uniform_weights():
    state = _engine(_gauss_spec()).init(jax.random.key(0), 256)
    np.testing.assert_allclose(
        np.asarray(state.log_weights), -np.log(256.0), rtol=1e-6
    )
    assert state.n_active is None  # single filters are never ragged


def test_step_outputs():
    flt = _engine(_gauss_spec())
    state = flt.init(jax.random.key(0), 256)
    new_state, out = flt.step(state, jnp.float32(0.5), jax.random.key(1))
    assert 1.0 <= float(out.ess) <= 256.0
    assert bool(out.resampled)  # ess_threshold=1.0 resamples always
    # after resampling, weights reset to uniform
    np.testing.assert_allclose(
        np.asarray(new_state.log_weights), -np.log(256.0), rtol=1e-6
    )
    assert int(new_state.step) == 1


def test_adaptive_resampling_skips():
    """With a flat likelihood, ESS stays high and no resampling happens."""
    spec = SMCSpec(
        init=lambda k, n: {"x": jax.random.normal(k, (n,), jnp.float32)},
        transition=lambda k, p, s: p,
        loglik=lambda p, o, s: jnp.zeros_like(p["x"]),
    )
    flt = _engine(spec, ess_threshold=0.5)
    state = flt.init(jax.random.key(0), 128)
    _, out = flt.step(state, jnp.float32(0.0), jax.random.key(1))
    assert not bool(out.resampled)
    np.testing.assert_allclose(float(out.ess), 128.0, rtol=1e-5)


def test_run_tracks_drift():
    flt = _engine(_gauss_spec())
    obs = jnp.cumsum(jnp.full((60,), 0.1))  # target drifting at the model rate
    final, outs = flt.run(jax.random.key(0), obs, 512)
    est = np.asarray(outs.estimate["x"])
    err = np.abs(est[-20:] - np.asarray(obs[-20:]))
    assert err.mean() < 0.5


def test_log_evidence_finite_and_reasonable():
    flt = _engine(_gauss_spec())
    obs = jnp.cumsum(jnp.full((30,), 0.1))
    _, outs = flt.run(jax.random.key(0), obs, 256)
    lz = np.asarray(outs.log_z_inc)
    assert np.isfinite(lz).all()
    # per-step log evidence for a well-matched model ~ -0.5*log(2*pi*var)
    assert lz.mean() > -3.0


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backends_agree_fp32(backend):
    flt = _engine(_gauss_spec(), backend=backend)
    obs = jnp.cumsum(jnp.full((20,), 0.1))
    _, outs = flt.run(jax.random.key(0), obs, 256)
    est = np.asarray(outs.estimate["x"])
    assert np.isfinite(est).all()
    # store for cross-check
    if not hasattr(test_backends_agree_fp32, "_ref"):
        test_backends_agree_fp32._ref = est
    else:
        np.testing.assert_allclose(
            est, test_backends_agree_fp32._ref, atol=1e-3
        )


def test_integer_states_pass_through():
    """SMC over pytrees with integer leaves (the LM-decode use case)."""
    spec = SMCSpec(
        init=lambda k, n: {
            "x": jnp.zeros((n,), jnp.float32),
            "tok": jnp.zeros((n, 4), jnp.int32),
        },
        transition=lambda k, p, s: {
            "x": p["x"] + 1.0,
            "tok": p["tok"] + 1,
        },
        loglik=lambda p, o, s: -jnp.square(p["x"] - o),
    )
    flt = _engine(spec)
    state = flt.init(jax.random.key(0), 64)
    new_state, out = flt.step(state, jnp.float32(1.0), jax.random.key(1))
    assert new_state.particles["tok"].dtype == jnp.int32
    assert out.estimate["tok"].dtype == jnp.int32  # ints not averaged
