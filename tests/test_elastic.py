"""Elastic particle budgets: the resize_slot budget switch and the
ESS-driven BudgetController.

The spine: resample-down to k is *bitwise* the count-aware systematic
draw at k over the slot's current posterior; resize-up re-draws at k with
the slot's log_uniform reset; budget transitions never recompile (traced
slot + count, the ragged-admission contract); the controller under
deadband + cooldown cannot oscillate; the global-budget arbiter grants
grows by ESS deficit and never exceeds the cap; and on a workload where
the controller never triggers, an elastic bank is bitwise identical to a
static ragged bank — dense, ragged, and meshed, across policies.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FilterBank,
    FilterConfig,
    SMCSpec,
    get_policy,
    resampling,
)
from repro.core.elastic import BudgetController, ElasticConfig
from tests._mp import run_with_devices

P = 256


def _toy_spec():
    """Difficulty-tunable SMC model: loglik = obs * N(0, 1) per particle,
    so per-slot observations set the weight spread (and thus the ESS)."""

    def init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}

    def transition(key, p, step):
        del step
        return {"x": jax.random.normal(key, p["x"].shape, jnp.float32)}

    def loglik(p, obs, step):
        del step
        return obs * p["x"]

    return SMCSpec(init, transition, loglik)


def _toy_bank(policy="fp32", backend="jnp", slots=3, thr=1.0):
    return FilterBank(
        _toy_spec(),
        FilterConfig(
            policy=get_policy(policy), backend=backend, ess_threshold=thr
        ),
        num_slots=slots,
    )


def _nonuniform_ragged_state(bank, key, counts):
    """A ragged bank state with informative (non-uniform) active weights —
    what a resize sees mid-flight."""
    state = bank.init(key, P, n_active=jnp.asarray(counts, jnp.int32))
    lw = jax.random.normal(jax.random.fold_in(key, 1), (bank.num_slots, P))
    lane = np.arange(P)
    mask = lane[None, :] < np.asarray(counts)[:, None]
    lw = jnp.where(
        jnp.asarray(mask), lw.astype(state.log_weights.dtype), -jnp.inf
    )
    return state._replace(log_weights=lw)


# ---------------------------------------------------------------------------
# resize_slot: the budget-switch primitive


def test_resize_down_bitwise_equals_count_aware_draw():
    """Resample-down to k == the count-aware (masked) systematic draw at
    k over the slot's current posterior, bit for bit, via the real traced
    jit path."""
    pol = get_policy("fp32")
    bank = _toy_bank()
    state = _nonuniform_ragged_state(bank, jax.random.key(1), [P, P, 128])
    slot, k = 1, 64
    key = jax.random.key(5)
    new = bank.jit_resize_slot(
        state, jnp.int32(slot), key, jnp.int32(k)
    )

    w = resampling.reference_normalize(state.log_weights[slot], pol)[0]
    anc = resampling.MASKED_RESAMPLERS["systematic"](
        key[None], w[None], pol, jnp.asarray([k], jnp.int32)
    )[0]
    expected = jnp.take(state.particles["x"][slot], anc, axis=0)
    np.testing.assert_array_equal(
        np.asarray(new.particles["x"][slot, :k]),
        np.asarray(expected[:k]),
    )
    # weights: uniform -log k over the new active prefix, -inf beyond
    lw = np.asarray(new.log_weights)
    assert (lw[slot, :k] == np.asarray(new.log_uniform)[slot]).all()
    assert np.isneginf(lw[slot, k:]).all()
    assert np.asarray(new.n_active).tolist() == [P, k, 128]
    # a resize is not a filter step
    np.testing.assert_array_equal(
        np.asarray(new.step), np.asarray(state.step)
    )
    # other slots bitwise untouched
    for s in (0, 2):
        np.testing.assert_array_equal(
            np.asarray(new.particles["x"][s]),
            np.asarray(state.particles["x"][s]),
        )
        np.testing.assert_array_equal(
            np.asarray(new.log_weights[s]),
            np.asarray(state.log_weights[s]),
        )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_resize_up_redraws_from_old_active_prefix(backend):
    """Resample-up to k re-draws k lanes from the old n-lane posterior:
    no ancestor may come from an inactive lane (sentinel check), and the
    slot restarts on uniform weights at the new count."""
    bank = _toy_bank(backend=backend)
    n_old, k = 16, 128
    state = _nonuniform_ragged_state(bank, jax.random.key(2), [P, n_old, P])
    sentinel = 7777.0
    x = np.array(state.particles["x"])
    x[1, n_old:] = sentinel
    state = state._replace(particles={"x": jnp.asarray(x)})

    new = bank.jit_resize_slot(
        state, jnp.int32(1), jax.random.key(6), jnp.int32(k)
    )
    got = np.asarray(new.particles["x"][1, :k])
    assert (got != sentinel).all(), "resize drew an inactive ancestor"
    lw = np.asarray(new.log_weights)
    assert (lw[1, :k] == np.asarray(new.log_uniform)[1]).all()
    assert np.isneginf(lw[1, k:]).all()
    assert int(np.asarray(new.n_active)[1]) == k
    # the resized slot keeps filtering: next-step ESS bounded by the
    # new budget
    ks = jax.random.split(jax.random.key(7), 3)
    _, out = bank.jit_step(
        new, jnp.asarray([0.5, 0.5, 0.5], jnp.float32), ks
    )
    assert np.asarray(out.ess)[1] <= k + 1e-3


def test_resize_no_recompile_across_budget_transitions():
    """Budget switches are traced in both slot and count: any number of
    distinct transitions compiles exactly once."""
    bank = _toy_bank()
    state = _nonuniform_ragged_state(bank, jax.random.key(3), [P, P, P])
    transitions = [(0, 64), (1, 8), (2, 32), (0, 128), (1, 256)]
    for i, (slot, k) in enumerate(transitions):
        state = bank.jit_resize_slot(
            state,
            jnp.int32(slot),
            jax.random.fold_in(jax.random.key(8), i),
            jnp.int32(k),
        )
        assert bank.jit_resize_slot._cache_size() == 1, (
            f"recompiled on transition {(slot, k)}"
        )
    assert np.asarray(state.n_active).tolist() == [128, 256, 32]


def test_resize_rejects_dense_bank():
    bank = _toy_bank()
    state = bank.init(jax.random.key(0), P)
    with pytest.raises(ValueError, match="ragged bank"):
        bank.resize_slot(state, 0, jax.random.key(1), 64)


# ---------------------------------------------------------------------------
# BudgetController: hysteresis, cooldown, arbiter


def test_config_validation():
    with pytest.raises(ValueError, match="grow_below"):
        ElasticConfig(grow_below=0.0, min_particles=8, max_particles=64)
    with pytest.raises(ValueError, match="shrink_above"):
        ElasticConfig(
            grow_below=64.0,
            shrink_above=100.0,
            min_particles=8,
            max_particles=64,
        )
    with pytest.raises(ValueError, match="min_particles"):
        ElasticConfig(grow_below=1.0, min_particles=64, max_particles=8)
    with pytest.raises(ValueError, match="global_budget"):
        ElasticConfig(
            grow_below=1.0,
            min_particles=64,
            max_particles=64,
            global_budget=32,
        )
    # deadband default: 4x the grow floor
    cfg = ElasticConfig(grow_below=16.0, min_particles=8, max_particles=64)
    assert cfg.shrink_above == 64.0


@pytest.mark.parametrize("seed", range(8))
def test_controller_monotone_under_count_proportional_ess(seed):
    """Deterministic no-oscillation: with ESS proportional to the count
    (the model the deadband is sized for — a x2 step doubles/halves the
    ESS), every slot's budget trajectory is monotone and converges; once
    stable, the controller stays silent."""
    rng = np.random.default_rng(seed)
    nslots = 5
    cfg = ElasticConfig(
        grow_below=64.0,
        shrink_above=float(rng.choice([128.0, 192.0, 256.0])),
        min_particles=16,
        max_particles=1024,
        cooldown=int(rng.integers(0, 4)),
    )
    ctrl = BudgetController(cfg, nslots)
    ratios = rng.uniform(0.05, 2.0, nslots)
    n = rng.choice([16, 32, 64, 128, 256, 512, 1024], nslots).astype(
        np.int64
    )
    busy = np.ones(nslots, bool)
    kinds = [[] for _ in range(nslots)]
    late = 0
    for t in range(64):
        decisions = ctrl.observe(ratios * n, n, busy)
        for d in decisions:
            assert d.granted  # no global budget: everything grants
            kinds[d.slot].append(d.kind)
            n[d.slot] = d.new
        if t >= 32:
            late += len(decisions)
    for k_list in kinds:
        assert len(set(k_list)) <= 1, f"direction reversed: {k_list}"
    assert late == 0, "controller still active after convergence window"


def _no_oscillation_property(seed: int) -> None:
    """Under arbitrary (adversarial) ESS traces, granted changes on one
    slot are always >= cooldown ticks apart — so a grow->shrink->grow
    needs >= 2 cooldown windows — and a granted grow never lifts the busy
    total above the global budget."""
    rng = np.random.default_rng(seed)
    nslots = int(rng.integers(1, 7))
    grow = float(rng.uniform(1.0, 200.0))
    cfg = ElasticConfig(
        grow_below=grow,
        shrink_above=grow * float(rng.uniform(2.0, 6.0)),
        min_particles=8,
        max_particles=2048,
        cooldown=int(rng.integers(1, 5)),
        global_budget=(
            int(rng.integers(64, 8192)) if rng.random() < 0.5 else None
        ),
    )
    ctrl = BudgetController(cfg, nslots)
    ladder = np.asarray([8, 16, 32, 64, 128, 256, 512, 1024, 2048])
    n = rng.choice(ladder, nslots).astype(np.int64)
    busy = rng.random(nslots) < 0.9
    granted = [[] for _ in range(nslots)]
    for t in range(100):
        if rng.random() < 0.15:  # churn: a request arrives or retires
            s = int(rng.integers(nslots))
            busy[s] = not busy[s]
            if busy[s]:
                n[s] = int(rng.choice(ladder))
                ctrl.slot_admitted(s)
        ess = rng.uniform(0.0, grow * 8.0, nslots)
        ess[rng.random(nslots) < 0.05] = np.nan  # collapsed slots
        grew = False
        for d in ctrl.observe(ess, n, busy):
            assert busy[d.slot], "resized an idle slot"
            if not d.granted:
                assert d.kind == "grow"  # only grows can be denied
                continue
            granted[d.slot].append((t, d.kind))
            n[d.slot] = d.new
            grew = grew or d.kind == "grow"
        assert cfg.min_particles <= n.min() and n.max() <= cfg.max_particles
        if grew and cfg.global_budget is not None:
            assert n[busy].sum() <= cfg.global_budget
    for evs in granted:
        for (t0, _), (t1, _) in zip(evs, evs[1:]):
            assert t1 - t0 >= cfg.cooldown, (
                f"changes {t1 - t0} ticks apart < cooldown {cfg.cooldown}"
            )
        for (t0, k0), (_, k1), (t2, k2) in zip(evs, evs[1:], evs[2:]):
            if k0 == "grow" and k1 == "shrink" and k2 == "grow":
                assert t2 - t0 >= 2 * cfg.cooldown


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_controller_never_oscillates_within_cooldown(seed):
        _no_oscillation_property(seed)

except ImportError:
    # hypothesis not in the container: same property, seeded sweep

    @pytest.mark.parametrize("seed", range(60))
    def test_controller_never_oscillates_within_cooldown(seed):
        _no_oscillation_property(seed)


def test_arbiter_grants_by_ess_deficit_and_retries_denied():
    """Tight global budget: the deepest-deficit slot grows first, the
    rest are denied without cooldown and retry — succeeding the moment a
    retire frees lanes."""
    cfg = ElasticConfig(
        grow_below=64.0,
        min_particles=32,
        max_particles=512,
        cooldown=2,
        global_budget=640,
    )
    ctrl = BudgetController(cfg, 3)
    n = np.asarray([256, 128, 128], np.int64)  # total 512
    busy = np.ones(3, bool)
    ess = np.asarray([10.0, 40.0, 5.0])  # deficits: 54, 24, 59
    d = ctrl.observe(ess, n, busy)
    assert [(x.slot, x.kind, x.granted) for x in d] == [
        (2, "grow", True),   # deficit 59: 512+128 = 640 fits exactly
        (0, "grow", False),  # deficit 54: +256 would blow the cap
        (1, "grow", False),  # deficit 24
    ]
    n[2] = 256  # total 640 == cap
    # next tick: still starving, still no room — denied again (denials
    # charge no cooldown, so the retry happens every tick)
    d = ctrl.observe(ess, n, busy)
    assert [(x.slot, x.granted) for x in d if x.kind == "grow"] == [
        (0, False),
        (1, False),
    ]
    # slot 2 retires: its lanes leave the busy total and the deepest
    # remaining deficit gets them
    busy[2] = False
    d = ctrl.observe(ess, n, busy)
    granted = [(x.slot, x.new) for x in d if x.granted]
    assert granted == [(0, 512)]  # 384 + 512 - 256 -> not over 640
    # slot 1 was denied again this tick (the cap is full once more):
    # 2 denials on each of the first two ticks, 1 on the third
    assert ctrl.stats["denied_grows"] == 5


def test_nan_ess_counts_as_collapse():
    """A fully collapsed slot (NaN ESS from 0/0 weight sums) must read as
    maximal deficit, not poison the comparison."""
    cfg = ElasticConfig(grow_below=64.0, min_particles=32, max_particles=256)
    ctrl = BudgetController(cfg, 2)
    d = ctrl.observe(
        np.asarray([np.nan, 100.0]),
        np.asarray([64, 64], np.int64),
        np.ones(2, bool),
    )
    assert [(x.slot, x.kind, x.new) for x in d] == [(0, "grow", 128)]
    assert d[0].deficit == 64.0


# ---------------------------------------------------------------------------
# acceptance: never-triggered elastic is bitwise a static ragged bank

_NEVER = dict(grow_below=1.0, shrink_above=1e6, min_particles=8)


@pytest.mark.parametrize("pname", ["fp32", "bf16", "fp16"])
@pytest.mark.parametrize("variant", ["dense", "ragged"])
def test_never_triggered_elastic_bitwise_static(pname, variant):
    """Uniform-difficulty workload, thresholds outside the ESS range: the
    controller proposes nothing and the elastic loop's bank state stays
    bitwise identical to a plain static bank, every step."""
    mk = lambda: _toy_bank(policy=pname)  # noqa: E731
    bank_s, bank_e = mk(), mk()
    if variant == "ragged":
        n_active = jnp.asarray([P, 16, 64], jnp.int32)
        budgets = np.asarray([P, 16, 64], np.int64)
        kw = dict(n_active=n_active)
    else:
        budgets = np.full(3, P, np.int64)
        kw = {}
    ss = bank_s.init(jax.random.key(1), P, **kw)
    se = bank_e.init(jax.random.key(1), P, **kw)
    ctrl = BudgetController(ElasticConfig(max_particles=P, **_NEVER), 3)
    busy = np.ones(3, bool)
    obs = jnp.full((3,), 0.2, jnp.float32)  # easy: ESS ~ 0.96 n
    for t in range(6):
        ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 3)
        ss, out_s = bank_s.jit_step(ss, obs, ks)
        se, out_e = bank_e.jit_step(se, obs, ks)
        assert ctrl.observe(
            np.asarray(out_e.ess, np.float64), budgets, busy
        ) == []
        np.testing.assert_array_equal(
            np.asarray(ss.log_weights), np.asarray(se.log_weights)
        )
        np.testing.assert_array_equal(
            np.asarray(ss.particles["x"]), np.asarray(se.particles["x"])
        )
        np.testing.assert_array_equal(
            np.asarray(out_s.ess), np.asarray(out_e.ess)
        )
    if variant == "ragged":
        np.testing.assert_array_equal(
            np.asarray(ss.n_active), np.asarray(se.n_active)
        )
    assert ctrl.stats == {
        "grows": 0,
        "shrinks": 0,
        "denied_grows": 0,
        "denied_grows_latency": 0,
        "reseeds": 0,
    }


MESHED_NEVER_TRIGGER = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterBank, FilterConfig, SMCSpec, get_policy
from repro.core.elastic import BudgetController, ElasticConfig
from repro.compat import make_mesh

def toy():
    def init(key, n):
        return {{"x": jax.random.normal(key, (n,), jnp.float32)}}
    def transition(key, p, step):
        return {{"x": jax.random.normal(key, p["x"].shape, jnp.float32)}}
    def loglik(p, obs, step):
        return obs * p["x"]
    return SMCSpec(init, transition, loglik)

pol = get_policy("{policy}")
mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
mk = lambda: FilterBank(
    toy(), FilterConfig(policy=pol, ess_threshold=1.0, mesh=mesh),
    num_slots=2)
bank_s, bank_e = mk(), mk()
n_active = jnp.asarray([64, 32], jnp.int32)
ss = bank_s.init(jax.random.key(1), 64, n_active=n_active)
se = bank_e.init(jax.random.key(1), 64, n_active=n_active)
ctrl = BudgetController(
    ElasticConfig(grow_below=1.0, shrink_above=1e6,
                  min_particles=8, max_particles=64), 2)
budgets = np.asarray([64, 32], np.int64)
busy = np.ones(2, bool)
obs = jnp.full((2,), 0.2, jnp.float32)
for t in range(5):
    ks = jax.random.split(jax.random.fold_in(jax.random.key(2), t), 2)
    ss, _ = bank_s.jit_step(ss, obs, ks)
    se, oe = bank_e.jit_step(se, obs, ks)
    assert ctrl.observe(np.asarray(oe.ess, np.float64), budgets, busy) == []
    np.testing.assert_array_equal(np.asarray(ss.log_weights),
                                  np.asarray(se.log_weights))
    np.testing.assert_array_equal(np.asarray(ss.particles["x"]),
                                  np.asarray(se.particles["x"]))
np.testing.assert_array_equal(np.asarray(ss.n_active),
                              np.asarray(se.n_active))

# and the budget switch itself works on the sharded bank: resize slot 1,
# invariants hold, the bank keeps stepping
se = bank_e.jit_resize_slot(se, jnp.int32(1), jax.random.key(9),
                            jnp.int32(16))
assert np.asarray(se.n_active).tolist() == [64, 16]
lw = np.asarray(se.log_weights)
assert np.isneginf(lw[1, 16:]).all() and np.isfinite(lw[1, :16]).all()
ks = jax.random.split(jax.random.key(10), 2)
se, oe = bank_e.jit_step(se, obs, ks)
assert np.asarray(oe.ess)[1] <= 16 + 1e-3
print("meshed elastic ok")
"""


@pytest.mark.parametrize("policy", ["fp32", "bf16", "fp16"])
def test_never_triggered_elastic_bitwise_static_meshed(policy):
    out = run_with_devices(
        MESHED_NEVER_TRIGGER.format(policy=policy), devices=4
    )
    assert "meshed elastic ok" in out


# ---------------------------------------------------------------------------
# serving: --elastic wiring and truthful per-tick accounting


def _serve_spec(steps):
    """Decode-shaped spec with constant loglik: uniform weights, so the
    per-slot ESS is exactly the active count — a deterministic shrink
    workload for low thresholds."""

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok,
            reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    return SMCSpec(
        init, transition, lambda p, o, s: jnp.zeros_like(p["reward"])
    )


@pytest.mark.parametrize("async_admit", [False, True])
def test_serve_elastic_shrinks_and_accounts_truthfully(async_admit):
    """With ESS == n and thresholds that always shrink, every request
    walks down to min_particles; the particle-tick ledger follows the
    *current* budgets (strictly below the admission-time ledger) and the
    retire extraction respects the final budget."""
    from repro.launch.serve import run_continuous_batching

    steps = 8
    bank = FilterBank(
        _serve_spec(steps),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=0.0),
        num_slots=2,
    )
    stats = run_continuous_batching(
        bank,
        num_requests=4,
        max_steps=steps,
        particles=(4, 16),
        key=jax.random.key(7),
        min_steps=steps,
        async_admit=async_admit,
        elastic=ElasticConfig(
            grow_below=1.0,
            shrink_above=2.0,
            min_particles=4,
            max_particles=16,
            cooldown=1,
        ),
    )
    el = stats["elastic"]
    assert el["shrinks"] > 0 and el["grows"] == 0
    assert all(e["kind"] == "shrink" and e["granted"] for e in el["events"])
    for r in stats["results"]:
        assert r["final_particles"] == 4  # everyone walks to the floor
        assert r["final_particles"] <= r["particles"]
        assert r["tokens"].shape == (r["steps"],)
    # truthful ledger: admission-budget accounting would bill every
    # in-flight tick at the starting budget; shrinking mid-flight must
    # show up as strictly fewer active particle-ticks
    admission_ticks = sum(
        r["particles"] * (r["finished_tick"] - r["admitted_tick"])
        for r in stats["results"]
    )
    assert 0 < stats["active_particle_ticks"] < admission_ticks
    assert stats["padded_particle_ticks"] == 16 * stats["busy_slot_ticks"]


def test_serve_elastic_rejects_dense_particles():
    from repro.launch.serve import run_continuous_batching

    bank = FilterBank(
        _serve_spec(2),
        FilterConfig(policy=get_policy("fp32")),
        num_slots=2,
    )
    with pytest.raises(ValueError, match="ragged bank"):
        run_continuous_batching(
            bank,
            num_requests=2,
            max_steps=2,
            particles=8,
            key=jax.random.key(0),
            elastic=ElasticConfig(
                grow_below=1.0, min_particles=4, max_particles=8
            ),
        )


def test_controller_reseed_escalation():
    """A slot pinned collapsed (ESS under the grow floor) at max_particles
    for reseed_after consecutive ticks emits kind="reseed" (no count
    change), charges its cooldown, and restarts the persistence counter;
    a slot that recovers in between never escalates."""
    cfg = ElasticConfig(
        grow_below=8.0,
        shrink_above=32.0,
        cooldown=1,
        min_particles=4,
        max_particles=16,
        reseed_after=2,
    )
    ctrl = BudgetController(cfg, 2)
    busy = np.ones(2, bool)
    n = np.array([16, 8])
    ess = np.array([1.0, 16.0])  # slot 0 collapsed at max; slot 1 healthy
    assert ctrl.observe(ess, n, busy) == []  # persistence 1: not yet
    ds = ctrl.observe(ess, n, busy)  # persistence 2: escalate
    assert [(d.slot, d.kind, d.old, d.new, d.granted) for d in ds] == [
        (0, "reseed", 16, 16, True)
    ]
    assert ctrl.stats["reseeds"] == 1
    # Cooldown charged and counter reset: the next reseed needs the
    # cooldown to expire AND the collapse to persist reseed_after again.
    assert ctrl.observe(ess, n, busy) == []
    ds = ctrl.observe(ess, n, busy)
    assert [d.kind for d in ds] == ["reseed"]
    assert ctrl.stats["reseeds"] == 2

    # Recovery resets persistence: collapse, recover, collapse again is
    # only persistence 1 — no escalation.
    ctrl2 = BudgetController(cfg, 1)
    one = np.ones(1, bool)
    ctrl2.observe(np.array([1.0]), np.array([16]), one)
    ctrl2.observe(np.array([20.0]), np.array([16]), one)  # recovered
    assert ctrl2.observe(np.array([1.0]), np.array([16]), one) == []
    assert ctrl2.stats["reseeds"] == 0


def test_controller_reseed_disabled_by_default():
    """reseed_after=None (the default): a slot may stay collapsed at max
    forever without a reseed decision — the pre-escalation contract."""
    cfg = ElasticConfig(
        grow_below=8.0, min_particles=4, max_particles=16, cooldown=1
    )
    assert cfg.reseed_after is None
    ctrl = BudgetController(cfg, 1)
    for _ in range(10):
        assert (
            ctrl.observe(np.array([1.0]), np.array([16]), np.ones(1, bool))
            == []
        )
    assert ctrl.stats["reseeds"] == 0


def test_nonfinite_and_negative_ess_hardened():
    """Garbage ESS readings (±Inf from an overflowed weight sum, negative
    from a corrupted stat) read as *collapse* (deficit = the full grow
    floor), never as health: an Inf ESS must not satisfy shrink_above,
    and a negative ESS must not dodge the grow floor."""
    cfg = ElasticConfig(
        grow_below=8.0, shrink_above=32.0, min_particles=4, max_particles=64
    )
    for bad in (np.inf, -np.inf, -5.0, np.nan):
        ctrl = BudgetController(cfg, 2)
        (d,) = ctrl.observe(
            np.array([bad, 16.0]),
            np.array([16, 16], np.int64),
            np.ones(2, bool),
        )
        assert (d.slot, d.kind) == (0, "grow"), f"ess={bad}"
        assert d.deficit == cfg.grow_below
    # and a pinned-at-max slot with Inf ESS escalates to reseed like any
    # collapsed slot (the pre-hardening code shrank it instead)
    cfg = ElasticConfig(
        grow_below=8.0,
        shrink_above=32.0,
        min_particles=4,
        max_particles=16,
        cooldown=1,
        reseed_after=2,
    )
    ctrl = BudgetController(cfg, 1)
    one = np.ones(1, bool)
    assert ctrl.observe(np.array([np.inf]), np.array([16]), one) == []
    (d,) = ctrl.observe(np.array([np.inf]), np.array([16]), one)
    assert d.kind == "reseed"


def test_latency_denial_reason_and_counter():
    """A grow on a slot whose lane p95 already exceeds the tick deadline
    is denied with reason="latency" (counted separately from budget
    denials, no cooldown charge); the same grow grants the moment the
    lane is back under deadline — and with deadline_ms=None the arbiter
    is inert."""
    cfg = ElasticConfig(
        grow_below=8.0, min_particles=4, max_particles=64, cooldown=2
    )
    ctrl = BudgetController(cfg, 2)
    busy = np.ones(2, bool)
    ess = np.array([1.0, 1.0])
    n = np.array([16, 16], np.int64)
    ds = ctrl.observe(
        ess, n, busy,
        lane_p95_ms=np.array([50.0, 5.0]),
        deadline_ms=10.0,
    )
    by = {d.slot: d for d in ds}
    assert not by[0].granted and by[0].reason == "latency"
    assert by[1].granted and by[1].reason == ""
    assert ctrl.stats["denied_grows_latency"] == 1
    assert ctrl.stats["denied_grows"] == 0  # counted apart from budget
    assert ctrl.stats["grows"] == 1
    # no cooldown charged on the denial: the retry grants immediately
    # once the lane recovers (slot 1's granted grow did charge one)
    ds = ctrl.observe(
        ess, np.array([16, 32], np.int64), busy,
        lane_p95_ms=np.array([5.0, 5.0]),
        deadline_ms=10.0,
    )
    assert [(d.slot, d.granted) for d in ds] == [(0, True)]
    # deadline off: the same late lane is invisible to the arbiter
    ctrl2 = BudgetController(cfg, 1)
    (d,) = ctrl2.observe(
        np.array([1.0]), np.array([16], np.int64), np.ones(1, bool),
        lane_p95_ms=np.array([50.0]),
    )
    assert d.granted
    assert ctrl2.stats["denied_grows_latency"] == 0
    with pytest.raises(ValueError, match="lane_p95_ms"):
        BudgetController(cfg, 2).observe(
            ess, n, busy,
            lane_p95_ms=np.array([5.0]),
            deadline_ms=10.0,
        )


def test_serve_latency_denial_surfaced():
    """--tick-deadline-ms + --elastic end to end: an impossible deadline
    denies every grow with reason="latency" and the denial counter lands
    in stats["elastic"]."""
    from repro.launch.serve import run_continuous_batching

    steps = 6
    bank = FilterBank(
        _serve_spec(steps),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
        num_slots=2,
    )
    stats = run_continuous_batching(
        bank,
        num_requests=3,
        max_steps=steps,
        min_steps=steps,
        particles=(4, 16),
        key=jax.random.key(11),
        tick_deadline_ms=1e-6,
        elastic=ElasticConfig(
            grow_below=1e9,  # every slot always wants to grow
            min_particles=4,
            max_particles=16,
            cooldown=1,
        ),
    )
    el = stats["elastic"]
    assert el["grows"] == 0
    assert el["denied_grows_latency"] > 0
    denied = [e for e in el["events"] if not e["granted"]]
    assert denied and all(e.get("reason") == "latency" for e in denied)
    # containment, not starvation: every request still retires on budget
    assert [r["id"] for r in stats["results"]] == [0, 1, 2]


def test_controller_flags_cross_lane_grows_for_migration():
    """With lane_width given (the packed scheduler), a granted grow whose
    new budget exceeds its slot's lane width carries migrate=True; grows
    that fit in-lane, and all grows without lane_width, do not."""
    cfg = ElasticConfig(
        grow_below=8.0, min_particles=4, max_particles=64, cooldown=1
    )
    ctrl = BudgetController(cfg, 2)
    ds = ctrl.observe(
        np.array([1.0, 1.0]),
        np.array([16, 16]),
        np.ones(2, bool),
        lane_width=np.array([16, 64]),
    )
    by = {d.slot: d for d in ds}
    assert by[0].kind == "grow" and by[0].new == 32 and by[0].migrate
    assert by[1].kind == "grow" and by[1].new == 32 and not by[1].migrate

    ctrl = BudgetController(cfg, 1)
    (d,) = ctrl.observe(
        np.array([1.0]), np.array([16]), np.ones(1, bool)
    )
    assert not d.migrate

    with pytest.raises(ValueError, match="lane_width"):
        BudgetController(cfg, 2).observe(
            np.array([1.0, 1.0]),
            np.array([16, 16]),
            np.ones(2, bool),
            lane_width=np.array([16]),
        )


def test_controller_migration_bookkeeping():
    """slot_moved transfers cooldown/collapse history to the destination
    and clears the source; migration_blocked reclassifies a granted grow
    as denied while keeping the cooldown charged (placement backoff)."""
    cfg = ElasticConfig(
        grow_below=8.0, min_particles=4, max_particles=64, cooldown=3
    )
    ctrl = BudgetController(cfg, 2)
    busy = np.array([True, True])
    (d,) = ctrl.observe(
        np.array([1.0, 20.0]), np.array([8, 8]), busy
    )
    assert d.slot == 0 and d.kind == "grow" and d.granted
    assert ctrl.stats["grows"] == 1

    # The scheduler could not place the migration: grow becomes a denial,
    # and the charged cooldown holds (no immediate retry).
    ctrl.migration_blocked(0)
    assert ctrl.stats == {
        "grows": 0,
        "shrinks": 0,
        "denied_grows": 1,
        "denied_grows_latency": 0,
        "reseeds": 0,
    }
    assert ctrl.observe(np.array([1.0, 20.0]), np.array([8, 8]), busy) == []

    # A later granted grow that *does* migrate: history follows the slot.
    for _ in range(2):  # drain the cooldown
        ctrl.observe(np.array([20.0, 20.0]), np.array([8, 8]), busy)
    (d,) = ctrl.observe(np.array([1.0, 20.0]), np.array([8, 8]), busy)
    assert d.granted
    ctrl.slot_moved(0, 1)
    # Destination inherits the fresh cooldown: no resize for slot 1 until
    # it expires; the vacated source is clean for the next admission.
    assert ctrl.observe(np.array([20.0, 1.0]), np.array([8, 16]), busy) == []
    assert ctrl._cooldown[0] == 0 and ctrl._collapse[0] == 0


def test_serve_elastic_reseed_surfaced():
    """Serve-level failure recovery: slots pinned at max_particles with
    collapsed ESS re-seed (fresh cloud, step kept — requests still finish
    on schedule) and the events/stats surface kind="reseed"."""
    from repro.launch.serve import run_continuous_batching

    steps = 6
    bank = FilterBank(
        _serve_spec(steps),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
        num_slots=2,
    )
    stats = run_continuous_batching(
        bank,
        num_requests=4,
        max_steps=steps,
        min_steps=steps,
        particles=(2, 4),
        key=jax.random.key(3),
        elastic=ElasticConfig(
            # ESS == n for the uniform-weight spec, far below this floor:
            # every busy slot is collapsed; at max they escalate.
            grow_below=1e9,
            min_particles=2,
            max_particles=4,
            cooldown=1,
            reseed_after=1,
        ),
    )
    el = stats["elastic"]
    assert el["reseeds"] > 0
    kinds = {e["kind"] for e in el["events"]}
    assert "reseed" in kinds
    for e in el["events"]:
        if e["kind"] == "reseed":
            assert e["old"] == e["new"] == 4 and e["granted"]
    # Recovery never stalls completion: every request retires on budget.
    assert [r["id"] for r in stats["results"]] == [0, 1, 2, 3]
    assert all(len(r["tokens"]) == steps for r in stats["results"])
