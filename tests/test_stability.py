"""Property tests (hypothesis) for the stability primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import stability

finite_f = st.floats(
    min_value=-300.0, max_value=300.0, allow_nan=False, allow_infinity=False
)


@st.composite
def logw_arrays(draw, max_len=64):
    n = draw(st.integers(2, max_len))
    return np.array(draw(st.lists(finite_f, min_size=n, max_size=n)), np.float32)


@given(logw_arrays())
@settings(max_examples=50, deadline=None)
def test_logsumexp_matches_scipy(x):
    got = float(stability.logsumexp(jnp.asarray(x)))
    want = float(jax.scipy.special.logsumexp(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(logw_arrays())
@settings(max_examples=50, deadline=None)
def test_normalized_weights_sum_to_one(x):
    w, lz = stability.normalize_log_weights(jnp.asarray(x))
    # exp(x - lse) carries O(eps * |x|) relative error per weight — this is
    # exactly why the filter's estimators are scale-invariant (divide by the
    # actual sum); see core.filter._weighted_mean.
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-4)
    assert np.isfinite(float(lz))


@given(logw_arrays(), logw_arrays())
@settings(max_examples=50, deadline=None)
def test_lse_combine_associative_and_matches_concat(a, b):
    """Merging shard-local online states == LSE of the concatenation."""
    sa = stability.lse_update(stability.lse_init(), jnp.asarray(a))
    sb = stability.lse_update(stability.lse_init(), jnp.asarray(b))
    merged = stability.lse_combine(sa, sb)
    got = float(stability.lse_finalize(merged))
    want = float(
        jax.scipy.special.logsumexp(jnp.concatenate([jnp.asarray(a), jnp.asarray(b)]))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # commutativity
    merged2 = stability.lse_combine(sb, sa)
    np.testing.assert_allclose(
        float(stability.lse_finalize(merged2)), got, rtol=1e-6
    )


@given(logw_arrays())
@settings(max_examples=30, deadline=None)
def test_online_streaming_matches_two_pass(x):
    """Folding blocks one at a time == two-pass logsumexp (kernel contract)."""
    arr = jnp.asarray(x)
    state = stability.lse_init()
    for i in range(0, arr.shape[0], 8):
        state = stability.lse_update(state, arr[i : i + 8])
    np.testing.assert_allclose(
        float(stability.lse_finalize(state)),
        float(stability.logsumexp(arr)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_lse_all_neg_inf():
    x = jnp.full((16,), -jnp.inf, jnp.float32)
    assert float(stability.logsumexp(x)) == -jnp.inf
    w, lz = stability.normalize_log_weights(x)
    assert bool(jnp.isfinite(w).all())  # exp(-inf - 0) = 0, no NaN


def test_scaled_square_avoids_fp16_overflow():
    """Paper Eq. 3 vs Eq. 4 at the paper's intensity values."""
    vals = jnp.full((69,), 228.0, jnp.float16)  # foreground disk
    # naive: sum of raw squared diffs overflows fp16 (24025 * 69 >> 65504)
    naive_sum = jnp.sum((vals - 100.0) ** 2)
    assert bool(jnp.isinf(naive_sum))
    # stable: scale inside the square
    isq = jnp.float16((50.0 * 69) ** -0.5)
    stable_sum = jnp.sum(stability.scaled_square_diff(vals, jnp.float16(100.0), isq))
    assert bool(jnp.isfinite(stable_sum))


def test_stable_softmax_fp16_large_logits():
    x = jnp.asarray([300.0, 200.0, 100.0], jnp.float16)
    p = stability.stable_softmax(x, accum_dtype=jnp.float32)
    assert bool(jnp.isfinite(p).all())
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-3)


@given(st.lists(st.floats(0.01, 1.0), min_size=2, max_size=32))
@settings(max_examples=30, deadline=None)
def test_ess_bounds(ws):
    w = jnp.asarray(np.array(ws, np.float32))
    w = w / jnp.sum(w)
    ess = float(stability.effective_sample_size(w))
    assert 1.0 - 1e-4 <= ess <= w.shape[0] + 1e-4
