"""Serving-scheduler regressions: keyed budgets, batch-axis threading,
argument validation, and sync/async scheduler equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import (
    _batch_axis,
    _request_budgets,
    _request_particles,
    particle_size_classes,
    run_continuous_batching,
)


def test_particle_size_classes_ladder():
    """Power-of-two ladder from min to max, max always included."""
    assert particle_size_classes(256, 4096) == [256, 512, 1024, 2048, 4096]
    assert particle_size_classes(3, 20) == [3, 6, 12, 20]
    assert particle_size_classes(8, 8) == [8]
    with pytest.raises(ValueError, match="1 <= min <= max"):
        particle_size_classes(0, 8)
    with pytest.raises(ValueError, match="1 <= min <= max"):
        particle_size_classes(16, 8)


def test_request_particles_follow_the_key():
    """Per-request particle budgets are key-derived size classes: two seeds
    draw two mixes, one seed reproduces, every draw is on the ladder."""
    a = _request_particles(jax.random.key(0), 64, 4, 32)
    b = _request_particles(jax.random.key(1), 64, 4, 32)
    a2 = _request_particles(jax.random.key(0), 64, 4, 32)
    classes = set(particle_size_classes(4, 32))
    assert set(a.tolist()) <= classes
    assert not np.array_equal(a, b)
    np.testing.assert_array_equal(a, a2)


def test_request_budgets_follow_the_key():
    """Two seeds draw two workloads; one seed reproduces (the old code
    hardcoded np.random.default_rng(0), so --seed never changed traffic)."""
    a = _request_budgets(jax.random.key(0), 32, 1, 64)
    b = _request_budgets(jax.random.key(1), 32, 1, 64)
    a2 = _request_budgets(jax.random.key(0), 32, 1, 64)
    assert a.shape == (32,)
    assert (a >= 1).all() and (a <= 64).all()
    assert not np.array_equal(a, b)
    np.testing.assert_array_equal(a, a2)


def test_min_steps_validation_message():
    """The ValueError names both bounds — it used to print
    "{min_steps} > {max_steps}" even when the failure was min_steps < 0."""

    class _Bank:
        num_slots = 2

    with pytest.raises(ValueError, match=r"min_steps=-1.*max_steps=8"):
        run_continuous_batching(
            _Bank(),
            num_requests=2,
            max_steps=8,
            particles=2,
            key=jax.random.key(0),
            min_steps=-1,
        )
    with pytest.raises(ValueError, match=r"min_steps=9.*max_steps=8"):
        run_continuous_batching(
            _Bank(),
            num_requests=2,
            max_steps=8,
            particles=2,
            key=jax.random.key(0),
            min_steps=9,
        )


def test_batch_axis_raises_on_ambiguity():
    """A dimension that merely equals the batch count must not be guessed:
    the first-match rule silently picked the layer axis for square shapes."""
    x = jnp.zeros((2, 2, 7, 2, 32))  # (layers, batch, seq, kv_heads, dh)
    with pytest.raises(ValueError, match="ambiguous"):
        _batch_axis(x, 2)
    with pytest.raises(ValueError, match="no batch axis"):
        _batch_axis(x, 5)
    assert _batch_axis(jnp.zeros((4, 7, 32)), 7) == 1


def test_decode_spec_gather_threads_cache_batch_axis():
    """With particles == num_layers == kv_heads (triply square cache
    shapes), the decode spec's gather must still select ancestors along the
    true batch axis of every cache leaf."""
    from repro.configs import get_config, reduced_config
    from repro.core.precision import get_policy
    from repro.launch.serve import make_smc_decode_spec
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"))
    pol = get_policy("fp32")
    n, steps = 2, 6  # n == cfg.num_layers == cfg.num_kv_heads
    params = M.init_params(jax.random.key(1), cfg, pol.param_dtype)
    decode = jax.jit(lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol))
    spec = make_smc_decode_spec(
        params, cfg, pol, decode, temperature=1.0, steps=steps
    )
    assert spec.particle_axes is not None

    p = spec.init(jax.random.key(0), n)
    p = spec.transition(jax.random.key(2), p, jnp.int32(0))
    anc = jnp.asarray([1, 1], jnp.int32)
    g = spec.gather(p, anc)
    # leading-axis leaves
    np.testing.assert_array_equal(np.asarray(g["tok"]), np.asarray(p["tok"])[[1, 1]])
    np.testing.assert_array_equal(np.asarray(g["seq"]), np.asarray(p["seq"])[[1, 1]])
    # cache leaves: ancestors taken along each leaf's *true* batch axis
    flat_p = jax.tree.leaves(p["cache"])
    flat_g = jax.tree.leaves(g["cache"])
    flat_ax = jax.tree.leaves(spec.particle_axes["cache"])
    assert any(ax != 0 for ax in flat_ax)  # the layout that broke guessing
    for leaf_p, leaf_g, ax in zip(flat_p, flat_g, flat_ax):
        np.testing.assert_array_equal(
            np.asarray(leaf_g), np.take(np.asarray(leaf_p), [1, 1], axis=ax)
        )


def test_async_admit_matches_sync_when_slots_free():
    """With a slot for every request and no retirement before the last
    admission (equal budgets), the double-buffered path serves the
    identical schedule (same slots, admissions, tokens, latencies)."""
    from repro.core import FilterBank, FilterConfig, SMCSpec
    from repro.core.precision import get_policy

    steps = 5

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok,
            reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    spec = SMCSpec(init, transition, loglik)
    out = {}
    for mode in (False, True):
        bank = FilterBank(
            spec,
            FilterConfig(policy=get_policy("fp32"), ess_threshold=0.5),
            num_slots=4,
        )
        out[mode] = run_continuous_batching(
            bank,
            num_requests=4,
            max_steps=steps,
            particles=3,
            key=jax.random.key(7),
            arrival_every=1,
            min_steps=steps,  # equal budgets: no slot frees mid-admission
            async_admit=mode,
        )
    sync, async_ = out[False]["results"], out[True]["results"]
    assert len(sync) == len(async_) == 4
    for rs, ra in zip(sync, async_):
        assert rs["id"] == ra["id"]
        assert rs["steps"] == ra["steps"]
        assert rs["admitted_tick"] == ra["admitted_tick"]
        assert rs["finished_tick"] == ra["finished_tick"]
        np.testing.assert_array_equal(rs["tokens"], ra["tokens"])


def test_meshed_engine_rejects_non_leading_particle_axes():
    """Specs with non-leading particle axes (particle_axes set) fail fast
    under a meshed ParticleFilter (use FilterBank B=1) and under a meshed
    bank without the layout-aware gather/summary hooks — silent axis-0
    gathers would corrupt cache leaves."""
    from repro.core import FilterConfig, ParticleFilter, SMCSpec
    from repro.core.distributed import DistributedConfig, make_dist_bank_step
    from repro.core.precision import get_policy

    def init(key, n):
        del key
        return {"x": jnp.zeros((3, n))}  # particle axis 1, not leading

    spec = SMCSpec(
        init,
        lambda k, p, s: p,
        lambda p, o, s: jnp.zeros(p["x"].shape[1]),
        particle_axes={"x": 1},
    )
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="FilterBank"):
        ParticleFilter(spec, FilterConfig(mesh=mesh))
    with pytest.raises(ValueError, match="summary AND.*gather"):
        make_dist_bank_step(
            spec,
            get_policy("fp32"),
            DistributedConfig(mesh=mesh, axis=("data",), bank_axis="b"),
        )


def test_engine_rejects_disabled_exchange():
    """FilterConfig(mesh=...) with a zero period or out-of-range fraction
    must fail fast instead of silently never exchanging."""
    from repro.core import FilterConfig, ParticleFilter
    from repro.core.tracking import TrackerConfig, make_tracker_spec
    from repro.core.precision import get_policy

    spec = make_tracker_spec(TrackerConfig(num_particles=64), get_policy("fp32"))
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="exchange_every"):
        ParticleFilter(
            spec, FilterConfig(mesh=mesh, scheme="local", exchange_every=0)
        )
    with pytest.raises(ValueError, match="exchange_frac"):
        ParticleFilter(
            spec, FilterConfig(mesh=mesh, scheme="local", exchange_frac=0.0)
        )
