"""Multi-bank packing engine: size-class routing, spillover, sibling
entry-point sharing, cross-bank migration primitives, batched prefill,
and pipelined uploads.

The spine: routing is deterministic under the run key and work-conserving
(a request queues only while *no* wide-enough bank has a free slot); a
single-class packed family is bitwise the single-bank scheduler; sibling
banks share one compiled trace cache (N classes never N x compile);
export -> import migrates a slot across widths preserving its progress
and drawing only from its active posterior; the batched prefill pass is
exactly the inline per-position decode; and pipelined uploads are a pure
host-side reorder — the schedule and every result bitwise match plain
async.
"""

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FilterBank, FilterConfig, SMCSpec, get_policy
from repro.launch.serve import (
    SizeClassPacker,
    make_packed_banks,
    particle_size_classes,
    run_continuous_batching,
)
from tests._mp import run_with_devices

STEPS = 5


def _decode_spec(steps=STEPS):
    """Decode-shaped toy spec (tok/reward/cum_reward/seq) — the particle
    layout run_continuous_batching's retire path reads."""

    def init(key, n):
        del key
        return dict(
            tok=jnp.zeros((n,), jnp.int32),
            reward=jnp.zeros((n,), jnp.float32),
            cum_reward=jnp.zeros((n,), jnp.float32),
            seq=jnp.zeros((n, steps), jnp.int32),
        )

    def transition(key, p, step):
        tok = jax.random.randint(key, p["tok"].shape, 0, 100)
        reward = jax.random.uniform(
            jax.random.fold_in(key, 1), p["reward"].shape
        )
        pos = jnp.minimum(step, steps - 1)
        return dict(
            tok=tok,
            reward=reward,
            cum_reward=p["cum_reward"] + reward,
            seq=p["seq"].at[:, pos].set(tok),
        )

    def loglik(p, obs, step):
        del obs, step
        return p["reward"]

    return SMCSpec(init, transition, loglik)


def _config(thr=0.5):
    return FilterConfig(policy=get_policy("fp32"), ess_threshold=thr)


def _run(
    bank, *, particles, key=7, requests=6, arrival_every=1,
    async_admit=False, **kw,
):
    return run_continuous_batching(
        bank,
        num_requests=requests,
        max_steps=STEPS,
        particles=particles,
        key=jax.random.key(key),
        arrival_every=arrival_every,
        async_admit=async_admit,
        **kw,
    )


def _assert_same_results(a, b, fields=None):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        for f in fields or (
            "id",
            "steps",
            "particles",
            "final_particles",
            "admitted_tick",
            "finished_tick",
        ):
            assert ra[f] == rb[f], f
        np.testing.assert_array_equal(ra["tokens"], rb["tokens"])


# -- packer routing ---------------------------------------------------------


def test_make_packed_banks_ladder_and_slot_split():
    """One bank per ladder class; remainder slots go to the widest
    classes; fewer slots than classes fails fast."""
    banks = make_packed_banks(
        _decode_spec(), _config(), num_slots=7, p_min=4, p_max=16
    )
    assert sorted(banks) == particle_size_classes(4, 16) == [4, 8, 16]
    assert {w: b.num_slots for w, b in banks.items()} == {4: 2, 8: 2, 16: 3}
    with pytest.raises(ValueError, match="at least one slot per size class"):
        make_packed_banks(
            _decode_spec(), _config(), num_slots=2, p_min=4, p_max=16
        )


def test_packer_place_prefers_exact_then_promotes():
    """First-fit over width-sorted lanes: the home class wins while it has
    room, then the request spills to the next wider class, and None only
    when nothing wide enough is free."""
    lanes = [
        SimpleNamespace(width=4, free=[0]),
        SimpleNamespace(width=8, free=[1, 0]),
        SimpleNamespace(width=16, free=[]),
    ]
    packer = SizeClassPacker(lanes)
    assert packer.place(4).width == 4
    lanes[0].free.clear()
    assert packer.place(4).width == 8  # spillover: promoted one class
    assert packer.place(16) is None  # wide class full: nothing fits
    lanes[1].free.clear()
    assert packer.place(4) is None


def test_packer_first_fit_is_work_conserving():
    """Property: over random lane configurations and request streams,
    place() returns None only when no wide-enough lane has a free slot,
    and otherwise always the narrowest such lane (deterministic first
    fit — one seed, one schedule)."""
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings

    ladder = [2, 4, 8, 16]

    @settings(max_examples=200, deadline=None)
    @given(
        frees=st.lists(
            st.integers(min_value=0, max_value=3),
            min_size=len(ladder),
            max_size=len(ladder),
        ),
        budgets=st.lists(st.sampled_from(ladder), max_size=12),
    )
    def prop(frees, budgets):
        lanes = [
            SimpleNamespace(width=w, free=list(range(f)))
            for w, f in zip(ladder, frees)
        ]
        packer = SizeClassPacker(lanes)
        for b in budgets:
            fitting = [
                ln for ln in packer.lanes if ln.width >= b and ln.free
            ]
            lane = packer.place(b)
            if lane is None:
                assert not fitting  # never starves a placeable request
            else:
                assert lane is fitting[0]  # narrowest fitting lane
                lane.free.pop()

    prop()


# -- scheduler equivalences -------------------------------------------------


def test_single_class_packed_bitwise_matches_single_bank():
    """A one-class packed family is the single dense bank wearing the
    packed API: same admissions, same tokens, same ticks, bitwise."""
    single = _run(
        FilterBank(_decode_spec(), _config(), num_slots=4), particles=3
    )
    packed = _run(
        make_packed_banks(
            _decode_spec(), _config(), num_slots=4, p_min=3, p_max=3
        ),
        particles=3,
    )
    _assert_same_results(single["results"], packed["results"])
    for k in (
        "ticks",
        "busy_slot_ticks",
        "occupancy",
        "active_particle_ticks",
        "padded_particle_ticks",
    ):
        assert single[k] == packed[k], k
    assert packed["packed"]["spillover_admissions"] == 0
    assert single["packed"] is None


def test_packed_routing_deterministic_under_seed():
    """Two runs from the same key produce identical schedules and tokens;
    a different key produces a different workload."""

    def go(key):
        return _run(
            make_packed_banks(
                _decode_spec(), _config(), num_slots=4, p_min=2, p_max=8
            ),
            particles=(2, 8),
            key=key,
            requests=8,
        )

    a, b, c = go(11), go(11), go(12)
    _assert_same_results(a["results"], b["results"])
    assert a["ticks"] == b["ticks"]
    assert (
        a["packed"]["spillover_admissions"]
        == b["packed"]["spillover_admissions"]
    )
    tokens_differ = any(
        not np.array_equal(ra["tokens"], rc["tokens"])
        for ra, rc in zip(a["results"], c["results"])
    )
    assert tokens_differ or a["ticks"] != c["ticks"]


def test_packed_completes_all_requests_with_spillover():
    """A burst workload (all requests arrive at once) over a small family
    forces spillover; every request still completes at its own budget and
    the promotions are counted and charged as padding."""
    stats = _run(
        make_packed_banks(
            _decode_spec(), _config(), num_slots=3, p_min=2, p_max=8
        ),
        particles=(2, 8),
        requests=9,
        key=13,  # budget draw with repeated narrow classes: forces spillover
        arrival_every=0,  # burst: everything pending at tick 0
    )
    assert [r["id"] for r in stats["results"]] == list(range(9))
    for r in stats["results"]:
        assert len(r["tokens"]) == r["steps"]
        assert r["lane_width"] >= r["particles"]  # never demoted
    spill = stats["packed"]["spillover_admissions"]
    assert spill == sum(
        1 for r in stats["results"] if r["lane_width"] > r["particles"]
    )
    assert spill > 0
    # Padding ledger: the packed family bills lane width, the useful
    # ledger bills budgets — spillover makes them differ.
    assert (
        stats["packed"]["lane_particle_ticks"]
        > stats["active_particle_ticks"]
    )


def test_pipelined_uploads_bitwise_matches_async():
    """Pipelined uploads are a host-side reorder only: the admission
    schedule, token streams, and every counter match plain async; sync
    mode rejects the flag."""
    banks = lambda: make_packed_banks(  # noqa: E731
        _decode_spec(), _config(), num_slots=4, p_min=2, p_max=8
    )
    plain = _run(banks(), particles=(2, 8), requests=8, async_admit=True)
    piped = _run(
        banks(),
        particles=(2, 8),
        requests=8,
        async_admit=True,
        pipelined_uploads=True,
    )
    _assert_same_results(plain["results"], piped["results"])
    for k in ("ticks", "busy_slot_ticks", "active_particle_ticks"):
        assert plain[k] == piped[k], k
    with pytest.raises(ValueError, match="async_admit"):
        _run(banks(), particles=(2, 8), pipelined_uploads=True)


def test_packed_elastic_migrates_across_banks():
    """Grows past a lane's width migrate the slot to a wider bank (or are
    reclassified as blocked when none has room); migrated requests finish
    with their history intact at the grown budget."""
    from repro.core.elastic import ElasticConfig

    stats = _run(
        make_packed_banks(
            _decode_spec(), _config(thr=1.0), num_slots=4, p_min=2, p_max=8
        ),
        particles=(2, 8),
        requests=6,
        elastic=ElasticConfig(
            grow_below=1e9,  # every busy slot always wants to grow
            min_particles=2,
            max_particles=8,
            cooldown=1,
        ),
    )
    pk, el = stats["packed"], stats["elastic"]
    assert el["grows"] > 0
    assert pk["migrations"] + pk["migrations_blocked"] > 0
    migrated = [e for e in el["events"] if "migrated_to" in e]
    assert len(migrated) == pk["migrations"]
    for e in migrated:
        assert e["to_width"] >= e["new"] > e["from_width"]
    assert [r["id"] for r in stats["results"]] == list(range(6))
    for r in stats["results"]:
        assert len(r["tokens"]) == r["steps"]
        assert r["final_particles"] >= r["particles"]


def test_latency_summary_per_bank_and_deadline():
    """Every tick of every bank contributes one step wall-time sample;
    the summary carries per-bank and pooled percentiles plus the
    over-deadline count."""
    stats = _run(
        make_packed_banks(
            _decode_spec(), _config(), num_slots=4, p_min=2, p_max=8
        ),
        particles=(2, 8),
        tick_deadline_ms=0.0,  # everything is over a zero deadline
    )
    lat = stats["latency"]
    nb_lanes = len(stats["packed"]["classes"])
    assert lat["ticks"] == stats["ticks"] * nb_lanes
    assert lat["ticks_over_deadline"] == lat["ticks"]
    assert set(lat["per_bank"]) == set(stats["packed"]["classes"])
    for row in lat["per_bank"].values():
        assert row["ticks"] == stats["ticks"]
        assert 0.0 < row["p50_ms"] <= row["p95_ms"] <= row["max_ms"]


# -- engine primitives ------------------------------------------------------


def test_sibling_banks_share_compiled_entry_points():
    """sibling() hands the twin the donor's jitted callables (identity),
    so a family of class banks shares one trace cache: stepping the twin
    adds exactly one geometry trace, and re-stepping adds none."""
    bank = FilterBank(_decode_spec(), _config(), num_slots=3)
    state = bank.init(
        jax.random.key(0), 8, n_active=jnp.full((3,), 8, jnp.int32)
    )
    obs = jnp.zeros((3,), jnp.int32)
    bank.jit_step(state, obs, jax.random.split(jax.random.key(1), 3))
    base = bank.jit_step._cache_size()

    twin = bank.sibling(num_slots=2)
    assert twin.jit_step is bank.jit_step
    assert twin.num_slots == 2
    tstate = twin.init(
        jax.random.key(2), 4, n_active=jnp.full((2,), 4, jnp.int32)
    )
    tobs = jnp.zeros((2,), jnp.int32)
    tkeys = jax.random.split(jax.random.key(3), 2)
    twin.jit_step(tstate, tobs, tkeys)
    assert bank.jit_step._cache_size() == base + 1  # new geometry: once
    twin.jit_step(tstate, tobs, tkeys)
    assert bank.jit_step._cache_size() == base + 1  # then cached


def test_reseed_slot_keeps_progress_resets_cloud():
    """reseed_slot redraws one slot's particles from the prior with
    uniform weights while keeping its step counter and budget — recovery
    restarts the posterior, not the request."""
    bank = FilterBank(_decode_spec(), _config(thr=1.0), num_slots=2)
    state = bank.init(
        jax.random.key(0), 8, n_active=jnp.asarray([4, 8], jnp.int32)
    )
    obs = jnp.zeros((2,), jnp.int32)
    for t in range(3):
        state, _ = bank.jit_step(
            state, obs, jax.random.split(jax.random.key(t), 2)
        )
    before = np.asarray(state.particles["cum_reward"])
    reseeded = bank.jit_reseed_slot(state, jnp.int32(0), jax.random.key(9))
    assert np.asarray(reseeded.step).tolist() == [3, 3]  # progress kept
    assert int(np.asarray(reseeded.n_active)[0]) == 4  # budget kept
    after = np.asarray(reseeded.particles["cum_reward"])
    assert not np.array_equal(after[0], before[0])  # fresh cloud
    np.testing.assert_array_equal(after[1], before[1])  # other slot intact
    w = np.asarray(
        jnp.exp(reseeded.log_weights[0] - reseeded.log_uniform[0])
    )
    np.testing.assert_allclose(w[:4], 1.0, rtol=1e-6)  # uniform over n


def test_export_import_migrates_across_widths():
    """export_slot -> import_slot moves a slot between banks of different
    lane widths: the destination draw selects only the source's active
    lanes, weights reset uniform over the new count, and the step counter
    travels with it."""
    src = FilterBank(_decode_spec(), _config(thr=1.0), num_slots=2)
    dst = src.sibling(num_slots=2)
    s_state = src.init(
        jax.random.key(0), 4, n_active=jnp.asarray([3, 4], jnp.int32)
    )
    for t in range(2):
        s_state, _ = src.jit_step(
            s_state,
            jnp.zeros((2,), jnp.int32),
            jax.random.split(jax.random.key(t), 2),
        )
    d_state = dst.init(
        jax.random.key(1), 8, n_active=jnp.full((2,), 8, jnp.int32)
    )
    rows, log_w, step = src.jit_export_slot(s_state, jnp.int32(0))
    d_state = dst.jit_import_slot(
        d_state, jnp.int32(1), rows, log_w, jax.random.key(2),
        jnp.int32(6), step,
    )
    assert int(np.asarray(d_state.step)[1]) == 2  # progress travelled
    assert int(np.asarray(d_state.n_active)[1]) == 6
    # Imported lanes are ancestors drawn from the source's *active*
    # posterior only (lanes 0..2 of slot 0) — never its masked tail.
    src_active = set(np.asarray(s_state.particles["cum_reward"])[0, :3])
    imported = np.asarray(d_state.particles["cum_reward"])[1, :6]
    assert set(imported) <= src_active
    w = np.asarray(
        jnp.exp(d_state.log_weights[1] - d_state.log_uniform[1])
    )
    np.testing.assert_allclose(w[:6], 1.0, rtol=1e-6)


MESHED_PACKED_RESEED = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterConfig, SMCSpec, get_policy
from repro.compat import make_mesh
from repro.launch.serve import make_packed_banks

def toy():
    def init(key, n):
        return {"x": jax.random.normal(key, (n,), jnp.float32)}
    def transition(key, p, step):
        noise = jax.random.normal(key, p["x"].shape, jnp.float32)
        return {"x": 0.9 * p["x"] + 0.1 * noise}
    def loglik(p, obs, step):
        return -jnp.square(p["x"])
    return SMCSpec(init, transition, loglik)

mesh = make_mesh((2, 2), ("data", "model"),
                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
banks = make_packed_banks(
    toy(),
    FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0, mesh=mesh),
    num_slots=4, p_min=4, p_max=8)
assert sorted(banks) == [4, 8]
narrow, wide = banks[4], banks[8]

ns = narrow.init(jax.random.key(0), 4,
                 n_active=jnp.asarray([4, 3], jnp.int32))
ws = wide.init(jax.random.key(1), 8,
               n_active=jnp.asarray([8, 6], jnp.int32))
for t in range(3):
    ks = jax.random.fold_in(jax.random.key(2), t)
    ns, _ = narrow.jit_step(ns, jnp.zeros((2,), jnp.int32),
                            jax.random.split(ks, 2))
    ws, _ = wide.jit_step(ws, jnp.zeros((2,), jnp.int32),
                          jax.random.split(jax.random.fold_in(ks, 1), 2))

# Reseed slot 0 of the narrow class bank on the mesh: progress and
# budget kept, fresh cloud, sibling slot bitwise intact, placement kept.
before = np.asarray(ns.particles["x"])
rs = narrow.jit_reseed_slot(ns, jnp.int32(0), jax.random.key(9))
assert np.asarray(rs.step).tolist() == [3, 3]
assert np.asarray(rs.n_active).tolist() == [4, 3]
after = np.asarray(rs.particles["x"])
assert not np.array_equal(after[0], before[0])
np.testing.assert_array_equal(after[1], before[1])
w = np.asarray(jnp.exp(rs.log_weights[0] - rs.log_uniform[0]))
np.testing.assert_allclose(w[:4], 1.0, rtol=1e-6)
assert rs.particles["x"].sharding == ns.particles["x"].sharding
assert rs.log_weights.sharding == ns.log_weights.sharding

# Race the reseed against a cross-class migration: export the freshly
# reseeded slot and import it into the wide bank before either bank
# steps again.  The migrated slot must carry the reseeded posterior
# (active lanes only), the reseeded slot stays valid, and the wide
# bank's other slot is untouched.
w_before = np.asarray(ws.particles["x"])
rows, log_w, step = narrow.jit_export_slot(rs, jnp.int32(0))
ws2 = wide.jit_import_slot(ws, jnp.int32(1), rows, log_w,
                           jax.random.key(3), jnp.int32(6), step)
assert int(np.asarray(ws2.step)[1]) == 3
assert int(np.asarray(ws2.n_active)[1]) == 6
np.testing.assert_array_equal(np.asarray(ws2.particles["x"])[0],
                              w_before[0])
src_active = set(after[0, :4].tolist())
imported = np.asarray(ws2.particles["x"])[1, :6]
assert set(imported.tolist()) <= src_active
wi = np.asarray(jnp.exp(ws2.log_weights[1] - ws2.log_uniform[1]))
np.testing.assert_allclose(wi[:6], 1.0, rtol=1e-6)
assert ws2.particles["x"].sharding == ws.particles["x"].sharding

# Both banks keep stepping after the surgery with sane ESS.
rs, on = narrow.jit_step(rs, jnp.zeros((2,), jnp.int32),
                         jax.random.split(jax.random.key(7), 2))
ws2, ow = wide.jit_step(ws2, jnp.zeros((2,), jnp.int32),
                        jax.random.split(jax.random.key(8), 2))
assert np.isfinite(np.asarray(on.ess)).all()
assert np.isfinite(np.asarray(ow.ess)).all()
assert np.asarray(on.ess)[0] <= 4 + 1e-3
assert np.asarray(ow.ess)[1] <= 6 + 1e-3
print("meshed packed reseed ok")
"""


def test_reseed_slot_meshed_packed_races_migration():
    """reseed_slot on a 2x2-meshed size-class family: progress/budget
    kept and shardings preserved, and an immediate cross-class
    export -> import of the reseeded slot lands the fresh posterior in
    the wider bank without disturbing either bank's other slots."""
    out = run_with_devices(MESHED_PACKED_RESEED, devices=4)
    assert "meshed packed reseed ok" in out


# -- batched prefill --------------------------------------------------------


def test_prefill_pass_matches_inline_decode():
    """The batched prefill pass fills exactly the cache the inline
    per-position decode loop would; rows_for broadcasts one request's row
    over its slot's lanes with the last prompt token staged."""
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import PrefillRunner
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"))
    pol = get_policy("fp32")
    params = M.init_params(jax.random.key(1), cfg, pol.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )
    L, steps, width = 4, 3, 2
    pr = PrefillRunner(
        params, cfg, pol, decode, prompt_len=L, steps=steps, batch=2
    )
    pr.make_prompts(jax.random.key(5), 3)
    prompts = np.asarray(pr.prompts)
    assert prompts.shape == (3, L)
    assert (prompts >= 0).all() and (prompts < cfg.vocab_size).all()

    rows = pr.rows_for([0, 1, 2], [width] * 3)  # 2 passes: [0,1] + [2]+pad
    assert len(rows) == 3 and pr.batches == 2
    axes = jax.tree.leaves(
        pr.cache_axes, is_leaf=lambda x: isinstance(x, int)
    )
    for i in range(3):
        # Inline reference: scan the same decode step over the prompt's
        # first L-1 positions at batch 1.
        cache = M.init_cache(cfg, 1, pr.s_max, pol.compute_dtype)
        for t in range(L - 1):
            _, cache = decode(
                params, pr.prompts[i : i + 1, t], jnp.int32(t), cache
            )
        ref = jax.tree.leaves(cache)
        got = jax.tree.leaves(rows[i]["cache"])
        for r, g, ax in zip(ref, got, axes):
            g = np.asarray(g)
            for lane in range(width):  # every lane is the same row
                np.testing.assert_allclose(
                    np.take(g, lane, axis=ax),
                    np.take(np.asarray(r), 0, axis=ax),
                    rtol=2e-5,
                    atol=2e-5,
                )
        np.testing.assert_array_equal(
            np.asarray(rows[i]["tok"]), np.full((width,), prompts[i, -1])
        )
        assert not np.asarray(rows[i]["cum_reward"]).any()
        assert rows[i]["seq"].shape == (width, steps)

    with pytest.raises(ValueError, match="prompt_len"):
        PrefillRunner(
            params, cfg, pol, decode, prompt_len=0, steps=steps, batch=2
        )


def test_prefill_spec_offsets_decode_positions():
    """make_smc_decode_spec(prompt_len=L) sizes the cache for prompt +
    decode and starts decode at position L-1; prompt_len=0 keeps the
    original layout (the bitwise-compatibility guard)."""
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import make_smc_decode_spec
    from repro.models import model as M

    cfg = reduced_config(get_config("minitron-8b"))
    pol = get_policy("fp32")
    params = M.init_params(jax.random.key(1), cfg, pol.param_dtype)
    decode = jax.jit(
        lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol)
    )
    steps, L, n = 3, 4, 2
    plain = make_smc_decode_spec(
        params, cfg, pol, decode, temperature=1.0, steps=steps
    )
    offset = make_smc_decode_spec(
        params, cfg, pol, decode, temperature=1.0, steps=steps,
        prompt_len=L,
    )
    shape_of = lambda spec: [  # noqa: E731
        x.shape for x in jax.tree.leaves(spec.init(jax.random.key(0), n))
    ]
    # The prefill spec's cache leaves carry L extra positions.
    assert shape_of(plain) != shape_of(offset)
    p0 = offset.init(jax.random.key(0), n)
    p1 = offset.transition(jax.random.key(2), p0, jnp.int32(0))
    assert p1["tok"].shape == (n,)  # runs end to end at the offset
