"""Fault tolerance: health sentinels, fault injection, snapshot rollback,
and the serve escalation ladder.

The spine: every health rule derives from stats the scheduler already
holds (zero extra device passes) and trips exactly when its invariant
breaks; the fault injector's schedule is a pure function of the run key
(same key, same chaos, bit for bit); the snapshot ring holds real host
copies that later donated steps cannot corrupt; and the serve loop under
injected faults *contains* every fault class — requests either finish
with valid tokens or retire with an explicit error, never junk — while
with zero faults the whole monitoring layer is bitwise invisible.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChaosConfig,
    FaultInjector,
    FilterBank,
    FilterConfig,
    HealthConfig,
    HealthMonitor,
    SMCSpec,
    get_policy,
)
from repro.core.faults import (
    FAULT_CLASSES,
    poison_particle_rows,
    poison_weight_row,
)
from repro.core.health import health_counters, reset_health_counters
from repro.checkpoint import Checkpointer, SlotSnapshotRing

B = 4


def _healthy(n=B):
    return dict(
        ess=np.full(n, 50.0),
        log_z_inc=np.full(n, -1.0),
        max_loglik=np.full(n, -0.5),
        busy=np.ones(n, bool),
    )


# ---------------------------------------------------------------------------
# HealthMonitor: rules, incident lifecycle, counters


def test_config_validation():
    for kw in (
        dict(collapse_after=0),
        dict(divergence_after=0),
        dict(step_timeout_ms=0.0),
        dict(snapshot_every=0),
        dict(snapshot_depth=0),
        dict(max_step_retries=-1),
    ):
        with pytest.raises(ValueError):
            HealthConfig(**kw)


def test_nonfinite_rule_trips_and_only_on_busy_slots():
    mon = HealthMonitor(HealthConfig(), B)
    s = _healthy()
    s["ess"][1] = np.nan
    s["log_z_inc"][2] = np.inf
    s["max_loglik"][3] = np.nan
    s["busy"][3] = False  # idle: never judged
    alerts = mon.observe(5, **s)
    assert [(a.slot, a.kind) for a in alerts] == [
        (1, "nonfinite"),
        (2, "nonfinite"),
    ]
    assert mon.trips["nonfinite"] == 2
    assert mon.pending(0) is None and mon.pending(3) is None


def test_incident_alerts_ongoing_but_counts_once():
    """An open incident keeps alerting every unhealthy tick (the ladder
    escalates on those) but the trip counter counts incidents."""
    mon = HealthMonitor(HealthConfig(), B)
    s = _healthy()
    s["ess"][0] = np.nan
    for tick in (1, 2, 3):
        alerts = mon.observe(tick, **s)
        assert [a.slot for a in alerts] == [0]
    assert mon.trips["nonfinite"] == 1
    assert len(mon.events) == 1


def test_incident_closes_only_after_an_action_and_records_latency():
    mon = HealthMonitor(HealthConfig(), B)
    bad = _healthy()
    bad["ess"][2] = np.nan
    mon.observe(4, **bad)
    # healthy read with no action applied: the incident stays open (the
    # scheduler hasn't fixed anything — a transient would self-close and
    # hide an unactioned corruption)
    mon.observe(5, **_healthy())
    assert mon.pending(2) is not None
    mon.slot_action(2, "rollback", tick=5)
    assert mon.pending(2)["last_action_tick"] == 5
    mon.observe(6, **_healthy())
    assert mon.pending(2) is None
    (rec,) = mon.recovered
    assert rec == {
        "slot": 2,
        "kind": "nonfinite",
        "trip_tick": 4,
        "recovered_tick": 6,
        "latency_ticks": 2,
        "action": "rollback",
        "actions": ["rollback"],
    }
    assert mon.recoveries["rollback"] == 1


def test_stuck_rule_is_progress_integrity():
    mon = HealthMonitor(HealthConfig(), B)
    s = _healthy()
    alerts = mon.observe(
        3,
        **s,
        expected_step=np.array([3, 3, 3, 3]),
        observed_step=np.array([3, 1, 3, 3]),
    )
    assert [(a.slot, a.kind) for a in alerts] == [(1, "stuck")]


def test_divergence_needs_consecutive_ticks_and_resets():
    mon = HealthMonitor(HealthConfig(divergence_after=2), B)
    s = _healthy()
    s["log_z_inc"][0] = -1e9
    assert mon.observe(1, **s) == []  # persistence 1
    good = _healthy()
    mon.observe(2, **good)  # recovers: counter resets
    assert mon.observe(3, **s) == []  # persistence 1 again
    alerts = mon.observe(4, **s)
    assert [(a.slot, a.kind) for a in alerts] == [(0, "divergence")]


def test_collapse_rule_disabled_at_zero_threshold():
    mon = HealthMonitor(HealthConfig(collapse_below=0.0), B)
    s = _healthy()
    s["ess"][1] = 1e-9
    for t in range(6):
        assert mon.observe(t, **s) == []
    mon = HealthMonitor(
        HealthConfig(collapse_below=2.0, collapse_after=3), B
    )
    for t in range(2):
        assert mon.observe(t, **s) == []
    alerts = mon.observe(2, **s)
    assert [(a.slot, a.kind) for a in alerts] == [(1, "collapse")]


def test_severity_order_nonfinite_wins():
    mon = HealthMonitor(HealthConfig(divergence_after=1), B)
    s = _healthy()
    s["ess"][0] = np.nan
    s["log_z_inc"][0] = -np.inf  # also diverged-looking
    (a,) = mon.observe(
        1,
        **s,
        expected_step=np.array([1, 1, 1, 1]),
        observed_step=np.array([0, 1, 1, 1]),  # also stuck
    )
    assert a.kind == "nonfinite"


def test_slot_reset_and_moved_carry_incident_state():
    mon = HealthMonitor(HealthConfig(), B)
    bad = _healthy()
    bad["ess"][0] = np.nan
    mon.observe(1, **bad)
    mon.slot_action(0, "reseed", tick=1)
    mon.slot_moved(0, 3)
    assert mon.pending(0) is None
    assert mon.pending(3)["actions"] == ["reseed"]
    mon.observe(2, **_healthy())
    assert mon.recovered[0]["slot"] == 3
    # reset wipes a dead request's history
    mon.observe(3, **bad)
    mon.slot_reset(0)
    assert mon.pending(0) is None


def test_slot_failed_closes_as_containment():
    mon = HealthMonitor(HealthConfig(), B)
    bad = _healthy()
    bad["ess"][1] = np.nan
    mon.observe(7, **bad)
    mon.slot_failed(1, 9, "retire_error")
    assert mon.pending(1) is None
    assert mon.recoveries["retire_error"] == 1
    assert mon.recovered[0]["latency_ticks"] == 2
    # without an open incident it synthesizes one (kind unknown)
    mon.slot_failed(2, 10, "retire_error")
    assert mon.recovered[1]["kind"] == "unknown"


def test_watchdog_and_retry_counters_and_process_mirror():
    reset_health_counters()
    mon = HealthMonitor(HealthConfig(step_timeout_ms=10.0), B)
    assert not mon.step_watchdog(5.0)
    assert mon.step_watchdog(50.0)
    mon.step_retried()
    assert mon.watchdog_trips == 1 and mon.step_retries == 1
    bad = _healthy()
    bad["ess"][0] = np.nan
    mon.observe(1, **bad)
    c = health_counters()
    assert c["watchdog_trips"] == 1
    assert c["step_retries"] == 1
    assert c["trips_nonfinite"] == 1
    reset_health_counters()
    assert health_counters() == {}


# ---------------------------------------------------------------------------
# FaultInjector: deterministic schedule + hooks


def test_chaos_config_validation():
    with pytest.raises(ValueError, match="fault classes"):
        ChaosConfig(classes=("nan_lanes", "bogus"))
    with pytest.raises(ValueError, match="rounds"):
        ChaosConfig(rounds=0)
    with pytest.raises(ValueError, match="every"):
        ChaosConfig(every=0)
    with pytest.raises(ValueError, match="fail_attempts"):
        ChaosConfig(fail_attempts=0)


def test_schedule_is_a_pure_function_of_the_run_key():
    cfg = ChaosConfig(rounds=2, start_tick=3, every=2)
    a = FaultInjector(cfg, jax.random.key(42), num_slots=8, num_lanes=2)
    b = FaultInjector(cfg, jax.random.key(42), num_slots=8, num_lanes=2)
    c = FaultInjector(cfg, jax.random.key(43), num_slots=8, num_lanes=2)
    assert a.seed == b.seed and a.schedule == b.schedule
    assert a.seed != c.seed
    # shape: rounds x classes, ticks on the start + i*every grid
    assert len(a.schedule) == 2 * len(FAULT_CLASSES)
    assert [f.tick for f in a.schedule] == [
        3 + 2 * i for i in range(len(a.schedule))
    ]
    assert [f.kind for f in a.schedule] == list(FAULT_CLASSES) * 2
    # int seed passes straight through (host-side reproduction)
    d = FaultInjector(cfg, 1234, num_slots=8)
    assert d.seed == 1234


def test_target_slot_wraps_to_first_busy_and_defers():
    inj = FaultInjector(ChaosConfig(), jax.random.key(0), num_slots=4)
    fault = dataclasses.replace(inj.schedule[0], preferred=2)
    busy = np.array([True, False, False, False])
    assert inj.target_slot(fault, busy) == 0  # wrapped past 2,3
    busy[3] = True
    assert inj.target_slot(fault, busy) == 3
    assert inj.target_slot(fault, np.zeros(4, bool)) is None


def test_step_fails_bounded_then_succeeds():
    cfg = ChaosConfig(classes=("fail_step",), fail_attempts=2, start_tick=1)
    inj = FaultInjector(cfg, jax.random.key(1), num_slots=2, num_lanes=1)
    assert not inj.step_fails(0, 0, 0)  # before start_tick
    assert inj.step_fails(1, 0, 0)
    assert inj.step_fails(1, 0, 1)
    assert not inj.step_fails(1, 0, 2)  # the retry after backoff lands
    assert inj.exhausted
    (entry,) = inj.log
    assert entry["kind"] == "fail_step" and entry["tick"] == 1


def test_delay_and_drop_applied_once():
    cfg = ChaosConfig(
        classes=("delay_step", "drop_upload"), start_tick=0, every=1,
        delay_ms=7.5,
    )
    inj = FaultInjector(cfg, jax.random.key(2), num_slots=2, num_lanes=1)
    assert inj.step_delay_ms(0, 0) == 7.5
    assert inj.step_delay_ms(0, 0) == 0.0  # consumed
    drop = inj.take_drop_upload(5)
    assert drop is not None and drop.kind == "drop_upload"
    inj.applied(drop, 5, 1)
    assert inj.take_drop_upload(6) is None
    assert inj.exhausted and inj.stats["applied"] == 2


def _tiny_bank(slots=3, width=8, policy="fp32", ragged=True):
    def init(key, n):
        return {
            "x": jax.random.normal(key, (n,), jnp.float32),
            "tok": jnp.zeros((n,), jnp.int32),
        }

    def transition(key, p, step):
        del step
        x = 0.9 * p["x"] + 0.1 * jax.random.normal(
            key, p["x"].shape, jnp.float32
        )
        return {"x": x, "tok": p["tok"] + 1}

    def loglik(p, obs, step):
        del obs, step
        return -jnp.square(p["x"])

    bank = FilterBank(
        SMCSpec(init, transition, loglik),
        FilterConfig(policy=get_policy(policy), ess_threshold=1.0),
        num_slots=slots,
    )
    kw = (
        dict(n_active=jnp.full((slots,), width, jnp.int32)) if ragged else {}
    )
    return bank, bank.init(jax.random.key(3), width, **kw)


def test_poison_particle_rows_inexact_leaves_one_slot():
    bank, state = _tiny_bank()
    poisoned = poison_particle_rows(state, 1)
    x = np.asarray(poisoned.particles["x"])
    assert np.isnan(x[1]).all()
    np.testing.assert_array_equal(x[0], np.asarray(state.particles["x"][0]))
    np.testing.assert_array_equal(x[2], np.asarray(state.particles["x"][2]))
    # integer leaves are never scribbled
    np.testing.assert_array_equal(
        np.asarray(poisoned.particles["tok"]),
        np.asarray(state.particles["tok"]),
    )
    # the next step surfaces it as a non-finite slot stat
    ks = jax.random.split(jax.random.key(4), 3)
    _, out = bank.jit_step(poisoned, None, ks)
    assert not np.isfinite(np.asarray(out.ess)[1])
    assert np.isfinite(np.asarray(out.ess)[[0, 2]]).all()


def test_poison_weight_row_active_prefix_only():
    bank, _ = _tiny_bank(width=8)
    state = bank.init(
        jax.random.key(3), 8, n_active=jnp.asarray([8, 4, 8], jnp.int32)
    )
    poisoned = poison_weight_row(state, 1)
    lw = np.asarray(poisoned.log_weights)
    assert np.isposinf(lw[1, :4]).all()
    assert np.isneginf(lw[1, 4:]).all()  # padding mask untouched
    np.testing.assert_array_equal(lw[0], np.asarray(state.log_weights[0]))


# ---------------------------------------------------------------------------
# SlotSnapshotRing: host copies, depth, rollback semantics


def test_ring_push_pop_depth_and_isolation():
    ring = SlotSnapshotRing(depth=2)
    bank, state = _tiny_bank()
    for step in range(3):
        ring.push(
            5,
            jax.tree.map(lambda x: x[0], state.particles),
            state.log_weights[0],
            jnp.int32(step),
            n_active=jnp.int32(8),
            tick=step * 4,
        )
    assert ring.pushes == 3
    assert ring.latest(5)["step"] == 2  # depth 2: step 0 dropped
    # host copies: donating/overwriting the live state cannot reach them
    snap = ring.latest(5)
    live = np.asarray(state.particles["x"][0]).copy()
    state = poison_particle_rows(state, 0)
    np.testing.assert_array_equal(snap["particles"]["x"], live)
    assert np.isfinite(snap["log_w"]).any()
    # pop consumes newest-first (a poisoned snapshot is never retried)
    assert ring.pop(5)["step"] == 2
    assert ring.pop(5)["step"] == 1
    assert ring.pop(5) is None
    assert ring.rollbacks == 2
    assert ring.latest(4) is None


def test_ring_clear_move_and_persist(tmp_path):
    ring = SlotSnapshotRing(depth=1)
    row = {"x": np.arange(4, dtype=np.float32)}
    ring.push(0, row, np.zeros(4, np.float32), 7, n_active=4, tick=12)
    ring.move(0, 9)
    assert ring.latest(0) is None
    assert ring.latest(9)["step"] == 7
    ckpt = Checkpointer(str(tmp_path))
    ring.persist(ckpt, step=7)
    loaded, extra = ckpt.restore(7, {"9": {"x": np.zeros(4, np.float32)}})
    np.testing.assert_array_equal(np.asarray(loaded["9"]["x"]), row["x"])
    assert extra["9"] == {"step": 7, "n_active": 4, "tick": 12}
    ring.clear(9)
    assert ring.latest(9) is None
    with pytest.raises(ValueError, match="depth"):
        SlotSnapshotRing(depth=0)


# ---------------------------------------------------------------------------
# serve: containment under chaos, bitwise-invisible when idle


def _serve_spec(steps):
    """Decode-shaped spec whose likelihood reads *carried* state (AR(1)
    chain): poisoned particle rows stay poisoned through transitions
    until a ladder rung replaces the state — what containment must
    actually handle (a spec that re-derives reward from the step key
    would shrug the poison off by itself)."""

    def init(key, n):
        return {
            "x": jax.random.normal(key, (n,), jnp.float32),
            "reward": jnp.zeros((n,), jnp.float32),
            "cum_reward": jnp.zeros((n,), jnp.float32),
            "seq": jnp.zeros((n, steps), jnp.int32),
        }

    def transition(key, p, step):
        x = 0.9 * p["x"] + 0.1 * jax.random.normal(
            key, p["x"].shape, jnp.float32
        )
        reward = -jnp.square(x)
        tok = (jnp.abs(x) * 97.0).astype(jnp.int32) % 1000
        pos = jnp.minimum(step.astype(jnp.int32), steps - 1)
        return {
            "x": x,
            "reward": reward,
            "cum_reward": p["cum_reward"] + reward,
            "seq": p["seq"].at[:, pos].set(tok),
        }

    return SMCSpec(init, transition, lambda p, o, s: p["reward"])


def _serve_bank(steps, slots=3, policy="fp32"):
    return FilterBank(
        _serve_spec(steps),
        FilterConfig(policy=get_policy(policy), ess_threshold=1.0),
        num_slots=slots,
    )


@pytest.mark.parametrize("async_admit", [False, True])
def test_serve_contains_every_fault_class(async_admit):
    """All five fault classes injected into a live serve run: every
    incident closes (recovered or retired-with-error), every request
    either finishes with its full token budget or carries an explicit
    error — junk never leaks, the loop never hangs."""
    from repro.launch.serve import run_continuous_batching

    steps = 8
    stats = run_continuous_batching(
        _serve_bank(steps),
        num_requests=6,
        max_steps=steps,
        min_steps=steps,
        particles=(4, 8),
        key=jax.random.key(9),
        async_admit=async_admit,
        health=HealthConfig(step_timeout_ms=250.0, snapshot_every=3),
        chaos=ChaosConfig(start_tick=2, every=2, delay_ms=5.0),
    )
    h, c = stats["health"], stats["chaos"]
    assert c["applied"] > 0
    assert sum(h["trips"].values()) > 0
    assert sum(h["recoveries"].values()) > 0
    assert h["open_incidents"] == {}
    assert [r["id"] for r in stats["results"]] == list(range(6))
    for r in stats["results"]:
        if "error" in r:
            assert r["tokens"].size == 0
        else:
            assert r["tokens"].shape == (r["steps"],)
    # injected state faults were detected, not silently absorbed
    state_faults = [
        e for e in c["log"] if e["kind"] in ("nan_lanes", "inf_weights")
    ]
    if state_faults:
        assert h["trips"].get("nonfinite", 0) > 0
    if any(e["kind"] == "drop_upload" for e in c["log"]):
        assert h["trips"].get("stuck", 0) > 0


@pytest.mark.parametrize("async_admit", [False, True])
def test_serve_health_layer_bitwise_invisible_without_faults(async_admit):
    """Monitoring + snapshotting enabled but zero faults injected: the
    run is bitwise identical to one with no health layer at all, and no
    sentinel trips spuriously."""
    from repro.launch.serve import run_continuous_batching

    steps = 6
    runs = []
    for health in (None, HealthConfig(snapshot_every=2)):
        stats = run_continuous_batching(
            _serve_bank(steps),
            num_requests=5,
            max_steps=steps,
            particles=(4, 8),
            key=jax.random.key(21),
            async_admit=async_admit,
            health=health,
        )
        runs.append(stats)
    plain, monitored = runs
    assert plain["ticks"] == monitored["ticks"]
    for a, b in zip(plain["results"], monitored["results"]):
        assert (a["id"], a["steps"]) == (b["id"], b["steps"])
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert monitored["health"]["trips"] == {}
    assert monitored["health"]["open_incidents"] == {}
    assert monitored["health"]["snapshots"]["pushes"] > 0


def test_serve_rollback_restores_from_snapshot():
    """A fault landing after snapshots exist rolls back (the ring is
    consulted before reseed) and the request still finishes."""
    from repro.launch.serve import run_continuous_batching

    steps = 10
    stats = run_continuous_batching(
        _serve_bank(steps, slots=2),
        num_requests=2,
        max_steps=steps,
        min_steps=steps,
        particles=(4, 8),
        key=jax.random.key(5),
        health=HealthConfig(snapshot_every=2),
        chaos=ChaosConfig(
            classes=("nan_lanes",), start_tick=5, every=1,
        ),
    )
    h = stats["health"]
    assert h["snapshots"]["rollbacks"] >= 1
    assert h["recoveries"].get("rollback", 0) >= 1
    assert h["open_incidents"] == {}
    assert all("error" not in r for r in stats["results"])


def test_serve_precision_fallback_recovers_fp16_overflow():
    """The paper-motivated rung: a model whose likelihood overflows in
    fp16 (every slot non-finite from the first step) but is finite in
    fp32.  Reseed cannot fix it — the ladder migrates the slot into the
    fp32 fallback bank, where the incident closes and the request
    completes with real tokens."""
    from repro.launch.serve import run_continuous_batching

    steps = 8

    def overflow_spec():
        def init(key, n):
            return {
                "x": jax.random.normal(key, (n,), jnp.float32),
                "reward": jnp.zeros((n,), jnp.float32),
                "cum_reward": jnp.zeros((n,), jnp.float32),
                "seq": jnp.zeros((n, steps), jnp.int32),
            }

        def transition(key, p, step):
            x = 0.9 * p["x"] + 0.1 * jax.random.normal(
                key, p["x"].shape, jnp.float32
            )
            # The log-likelihood sits around -70000: representable in
            # fp32 (tiny spread, ESS ~ n) but beyond fp16's +-65504 —
            # every lane is -inf, the max-shift is -inf - -inf = NaN,
            # and the slot reads nonfinite on every step no matter how
            # often it is reseeded.  Only the precision rung fixes it.
            reward = -70000.0 - 0.01 * jnp.square(x)
            tok = (jnp.abs(x) * 97.0).astype(jnp.int32) % 1000
            pos = jnp.minimum(step.astype(jnp.int32), steps - 1)
            return {
                "x": x,
                "reward": reward,
                # lineage score keeps only the finite spread term (the
                # -70000 offset is constant across lanes): the retire
                # guard reads this, and -inf + -inf accumulation in the
                # fp16 phase would turn containment into a retire_error
                "cum_reward": p["cum_reward"] - 0.01 * jnp.square(x),
                "seq": p["seq"].at[:, pos].set(tok),
            }

        return SMCSpec(init, transition, lambda p, o, s: p["reward"])

    bank16 = FilterBank(
        overflow_spec(),
        FilterConfig(policy=get_policy("fp16"), ess_threshold=1.0),
        num_slots=1,
    )
    bank32 = FilterBank(
        overflow_spec(),
        FilterConfig(policy=get_policy("fp32"), ess_threshold=1.0),
        num_slots=1,
    )
    stats = run_continuous_batching(
        bank16,
        num_requests=1,
        max_steps=steps,
        min_steps=steps,
        particles=(8, 8),
        key=jax.random.key(2),
        health=HealthConfig(snapshot_every=100),
        fallback_bank=bank32,
    )
    h = stats["health"]
    assert h["fallback_migrations"] == 1
    assert h["recoveries"].get("fallback", 0) >= 1
    assert h["open_incidents"] == {}
    (res,) = stats["results"]
    assert "error" not in res
    assert res["tokens"].shape == (steps,)


def test_serve_fallback_requires_health():
    from repro.launch.serve import run_continuous_batching

    steps = 2
    with pytest.raises(ValueError, match="health"):
        run_continuous_batching(
            _serve_bank(steps),
            num_requests=1,
            max_steps=steps,
            particles=(4, 8),
            key=jax.random.key(0),
            fallback_bank=_serve_bank(steps, slots=1),
        )


def test_bench_json_stamps_health_counters(tmp_path, monkeypatch):
    """Every BENCH_*.json carries the process-wide health counters of
    the run that produced it."""
    common = pytest.importorskip("benchmarks.common")
    reset_health_counters()
    mon = HealthMonitor(HealthConfig(), 2)
    bad = _healthy(2)
    bad["ess"][0] = np.nan
    mon.observe(1, **bad)
    monkeypatch.chdir(tmp_path)
    path = common.write_bench_json("healthprobe", [])
    import json

    payload = json.loads(open(path).read())
    assert payload["health"]["trips_nonfinite"] == 1
    reset_health_counters()
