"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need the dev extra; the rest run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.core.likelihood import IntensityModel
from repro.core.precision import get_policy
from repro.kernels.likelihood import ops as lik_ops
from repro.kernels.likelihood import ref as lik_ref
from repro.kernels.logsumexp import ops as lse_ops
from repro.kernels.logsumexp import ref as lse_ref
from repro.kernels.resample import ops as res_ops
from repro.kernels.resample import ref as res_ref

SIZES = [7, 128, 1000, 8192, 65536]
DTYPES = [jnp.float32, jnp.float16, jnp.bfloat16]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
def test_logsumexp_kernel_sweep(n, dt):
    x = (jax.random.normal(jax.random.key(n), (n,), jnp.float32) * 50).astype(dt)
    w, m, lse = lse_ops.normalize_weights(x)
    wr, mr, lr = lse_ref.normalize_weights_ref(x)
    np.testing.assert_allclose(float(m), float(mr), rtol=1e-6)
    np.testing.assert_allclose(float(lse), float(lr), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(w, np.float32), np.asarray(wr, np.float32), atol=2e-3
    )
    assert w.dtype == dt


@pytest.mark.parametrize("block_rows", [8, 64, 256])
def test_logsumexp_block_shape_invariance(block_rows):
    """BlockSpec sweep (the paper's threads-per-block analogue): results
    must not depend on the launch geometry."""
    x = jax.random.normal(jax.random.key(0), (65536,), jnp.float32) * 30
    w, m, lse = lse_ops.normalize_weights(x, block_rows=block_rows)
    wr, mr, lr = lse_ref.normalize_weights_ref(x)
    np.testing.assert_allclose(float(lse), float(lr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)


def test_logsumexp_kernel_neg_inf_padding():
    x = jnp.asarray([-jnp.inf, 0.0, -jnp.inf, 1.0], jnp.float32)
    w, m, lse = lse_ops.normalize_weights(x)
    want = float(jnp.log(jnp.exp(0.0) + jnp.exp(1.0)))
    np.testing.assert_allclose(float(lse), want, rtol=1e-6)
    assert bool(jnp.isfinite(w).all())


@pytest.mark.parametrize("n", SIZES)
def test_cumsum_kernel_sweep(n):
    w = jax.random.uniform(jax.random.key(n + 1), (n,), jnp.float32)
    cs = res_ops.inclusive_cumsum(w)
    csr = res_ref.inclusive_cumsum_ref(w)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(csr), rtol=1e-5)


@pytest.mark.parametrize("n", SIZES)
def test_systematic_kernel_vs_ref(n):
    w = jax.random.uniform(jax.random.key(n + 2), (n,), jnp.float32)
    anc = np.asarray(res_ops.systematic_resample(jax.random.key(7), w))
    u0 = jax.random.uniform(jax.random.key(7), (), jnp.float32)
    ancr = np.asarray(res_ref.systematic_resample_ref(u0, w))
    diff = np.abs(anc - ancr)
    # identical except CDF-tie boundaries (different fp32 summation
    # grouping); those may differ by exactly one index
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.005
    assert (np.diff(anc) >= 0).all()


def test_systematic_kernel_counts_property():
    w = np.zeros(512, np.float32)
    w[100] = 0.5
    w[200] = 0.25
    w[300] = 0.25
    anc = np.asarray(
        res_ops.systematic_resample(jax.random.key(0), jnp.asarray(w))
    )
    counts = np.bincount(anc, minlength=512)
    assert counts[100] in (255, 256, 257)
    assert counts[200] in (127, 128, 129)
    assert counts[300] in (127, 128, 129)


@pytest.mark.parametrize("p", [4, 100, 512, 1000])
@pytest.mark.parametrize("j", [9, 69, 128])
@pytest.mark.parametrize(
    "pname", ["fp16", "bf16", "fp32", "bf16_mixed"]
)
def test_likelihood_kernel_sweep(p, j, pname):
    pol = get_policy(pname)
    model = IntensityModel(radius=4)
    patches = jax.random.uniform(
        jax.random.key(p * j), (p, j), jnp.float32, 60.0, 250.0
    )
    ll, m = lik_ops.intensity_loglik_with_max(patches, model, pol)
    accum16 = jnp.dtype(pol.accum_dtype).itemsize == 2
    llr, mr = lik_ref.intensity_loglik_ref(
        patches.astype(pol.compute_dtype),
        bg=model.background,
        fg=model.foreground,
        isq=(model.scale * j) ** -0.5,
        accum16=accum16,
    )
    np.testing.assert_allclose(
        np.asarray(ll, np.float32), np.asarray(llr, np.float32),
        rtol=2e-2, atol=0.5,
    )
    # the max may land one compute-dtype ulp apart (different reduction
    # grouping); at bf16 magnitudes ~250 one ulp is 2.0
    ulp = float(jnp.finfo(pol.compute_dtype).eps) * max(1.0, abs(float(mr)))
    np.testing.assert_allclose(float(m), float(mr), rtol=1e-3, atol=0.5 + ulp)


@pytest.mark.parametrize("p", [5, 100, 128, 129, 1000])
@pytest.mark.parametrize("pname", ["fp16", "bf16", "fp32", "fp16_mixed"])
def test_likelihood_pad_rows_never_win_the_max(p, pname):
    """P-axis pad rows score exactly 0 — a poisoned sentinel when every
    real row is negative (the common case).  The returned max must be the
    max over *real* rows at any precision, never the pad rows' 0."""
    pol = get_policy(pname)
    model = IntensityModel(radius=4)
    # Patches far from the foreground intensity: every real row's
    # log-likelihood is strongly negative, so any pad-row leak (score 0)
    # would win the running max outright.
    patches = jax.random.uniform(
        jax.random.key(p), (p, model.num_points), jnp.float32, 10.0, 30.0
    )
    ll, m = lik_ops.intensity_loglik_with_max(patches, model, pol)
    assert ll.shape == (p,)
    true_max = float(jnp.max(ll.astype(jnp.float32)))
    assert true_max < -0.5, "test needs all-negative real rows"
    # A pad leak would pull the max all the way up to 0; the legitimate
    # slack is one compute-dtype ulp (the fused max carries pre-rounding
    # fp32 values when the P axis needed no padding).
    assert float(m) < -0.5
    ulp = float(jnp.finfo(pol.compute_dtype).eps) * abs(true_max)
    np.testing.assert_allclose(float(m), true_max, atol=ulp, rtol=0)


def test_likelihood_kernel_matches_core_stable_path():
    """Kernel == core.likelihood (the jnp reference path used in filter)."""
    from repro.core import likelihood as core_lik

    pol = get_policy("fp32")
    model = IntensityModel(radius=4)
    patches = jax.random.uniform(
        jax.random.key(5), (256, model.num_points), jnp.float32, 60.0, 250.0
    )
    ll_kernel = lik_ops.intensity_loglik(patches, model, pol)
    ll_core = core_lik.intensity_loglik(patches, model, pol)
    np.testing.assert_allclose(
        np.asarray(ll_kernel), np.asarray(ll_core), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("nbank", [1, 3, 8])
@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
def test_logsumexp_batched_matches_per_row(nbank, dt):
    """Bank-batched kernel == the 1-D kernel applied row by row, bitwise:
    the per-row fp32 carries must not leak across bank rows."""
    x = (
        jax.random.normal(jax.random.key(nbank), (nbank, 1000), jnp.float32)
        * 40
    ).astype(dt)
    wb, mb, lseb = lse_ops.normalize_weights_batched(x)
    assert wb.shape == x.shape and mb.shape == (nbank,) and wb.dtype == dt
    for i in range(nbank):
        wi, mi, lsei = lse_ops.normalize_weights(x[i])
        np.testing.assert_array_equal(
            np.asarray(wb[i], np.float32), np.asarray(wi, np.float32)
        )
        np.testing.assert_array_equal(float(mb[i]), float(mi))
        np.testing.assert_array_equal(float(lseb[i]), float(lsei))


def test_logsumexp_batched_matches_jnp_reference():
    """Batched pallas vs the vmapped pure-jnp oracle."""
    x = jax.random.normal(jax.random.key(0), (4, 8192), jnp.float32) * 30
    wb, mb, lseb = lse_ops.normalize_weights_batched(x)
    wr, mr, lr = jax.vmap(lse_ref.normalize_weights_ref)(x)
    np.testing.assert_allclose(np.asarray(mb), np.asarray(mr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lseb), np.asarray(lr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(wb), np.asarray(wr), atol=1e-6)


@pytest.mark.parametrize("nbank", [1, 4])
def test_systematic_batched_matches_per_row(nbank):
    """Per-row keys ⇒ the batched resample kernel reproduces the 1-D kernel
    row by row (independent offsets, independent CDF carries)."""
    keys = jax.random.split(jax.random.key(3), nbank)
    w = jax.random.uniform(jax.random.key(4), (nbank, 1000), jnp.float32)
    ancb = np.asarray(res_ops.systematic_resample_batched(keys, w))
    assert ancb.shape == (nbank, 1000)
    for i in range(nbank):
        anci = np.asarray(res_ops.systematic_resample(keys[i], w[i]))
        np.testing.assert_array_equal(ancb[i], anci)
        assert (np.diff(ancb[i]) >= 0).all()


def test_systematic_batched_rows_differ():
    """Different per-row keys must give different offsets (no accidental
    key sharing across the bank)."""
    keys = jax.random.split(jax.random.key(5), 3)
    w = jnp.tile(
        jax.random.uniform(jax.random.key(6), (1, 512), jnp.float32), (3, 1)
    )
    anc = np.asarray(res_ops.systematic_resample_batched(keys, w))
    assert not np.array_equal(anc[0], anc[1])
    assert not np.array_equal(anc[1], anc[2])


def test_likelihood_backend_hook_matches_core():
    """The Backend registry's ``intensity_loglik`` hook (what
    ``backend="pallas"`` tracking now dispatches on) == core.likelihood."""
    from repro.core import likelihood as core_lik
    from repro.core.engine import get_backend

    assert get_backend("jnp").intensity_loglik is None  # jnp uses the core path
    hook = get_backend("pallas").intensity_loglik
    assert hook is not None
    pol = get_policy("fp32")
    model = IntensityModel(radius=4)
    patches = jax.random.uniform(
        jax.random.key(11), (256, model.num_points), jnp.float32, 60.0, 250.0
    )
    np.testing.assert_allclose(
        np.asarray(hook(patches, model, pol)),
        np.asarray(core_lik.intensity_loglik(patches, model, pol)),
        rtol=1e-5,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# Masked (ragged-bank) kernels: a masked row with n_active = n must be
# *bitwise* the unmasked kernel on the width-n prefix — whatever junk the
# inactive lanes hold (including non-finite values) — across precisions.


def _junk_rows(key, nbank, width, counts, dt):
    """Bank rows whose active prefixes are normal draws and whose inactive
    tails are adversarial junk (huge values, nan, inf)."""
    x = (
        jax.random.normal(key, (nbank, width), jnp.float32) * 40
    ).astype(dt)
    x = np.array(x)  # ml_dtypes-backed numpy view, assignable
    junk = [3e4, float("nan"), float("inf"), float("-inf")]
    for i, n in enumerate(counts):
        for j in range(n, width):
            x[i, j] = junk[(i + j) % len(junk)]
    return jnp.asarray(x)


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
def test_masked_logsumexp_matches_unmasked_prefix_bitwise(dt):
    counts = [1000, 517, 128, 7]
    x = _junk_rows(jax.random.key(1), len(counts), 1000, counts, dt)
    n_act = jnp.asarray(counts, jnp.int32)
    wm, mm, lsem = lse_ops.normalize_weights_masked(x, n_act)
    assert wm.dtype == dt
    for i, n in enumerate(counts):
        wi, mi, lsei = lse_ops.normalize_weights(x[i, :n])
        np.testing.assert_array_equal(
            np.asarray(wm[i, :n], np.float32), np.asarray(wi, np.float32)
        )
        np.testing.assert_array_equal(float(mm[i]), float(mi))
        np.testing.assert_array_equal(float(lsem[i]), float(lsei))
        # inactive lanes: weight exactly 0, junk never leaks
        assert (np.asarray(wm[i, n:], np.float32) == 0.0).all()


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: d.__name__)
def test_masked_full_width_bitwise_dense(dt):
    """n_active = P on every row == the dense batched kernel, bitwise."""
    x = (
        jax.random.normal(jax.random.key(2), (3, 1000), jnp.float32) * 40
    ).astype(dt)
    full = jnp.full((3,), 1000, jnp.int32)
    wm, mm, lsem = lse_ops.normalize_weights_masked(x, full)
    wb, mb, lseb = lse_ops.normalize_weights_batched(x)
    np.testing.assert_array_equal(
        np.asarray(wm, np.float32), np.asarray(wb, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(mm), np.asarray(mb))
    np.testing.assert_array_equal(np.asarray(lsem), np.asarray(lseb))


def test_masked_systematic_matches_unmasked_prefix_bitwise():
    counts = [1000, 517, 128, 7]
    keys = jax.random.split(jax.random.key(3), len(counts))
    w = jax.random.uniform(jax.random.key(4), (len(counts), 1000), jnp.float32)
    wj = np.array(w)
    for i, n in enumerate(counts):  # junk weights on inactive lanes
        wj[i, n:] = [99.0, np.nan][i % 2]
    n_act = jnp.asarray(counts, jnp.int32)
    ancm = np.asarray(
        res_ops.systematic_resample_masked(keys, jnp.asarray(wj), n_act)
    )
    for i, n in enumerate(counts):
        anci = np.asarray(res_ops.systematic_resample(keys[i], w[i, :n]))
        np.testing.assert_array_equal(ancm[i, :n], anci)
        assert (ancm[i, :n] < n).all()  # never an inactive ancestor


def test_masked_systematic_full_width_bitwise_dense():
    keys = jax.random.split(jax.random.key(5), 3)
    w = jax.random.uniform(jax.random.key(6), (3, 1000), jnp.float32)
    full = jnp.full((3,), 1000, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(res_ops.systematic_resample_masked(keys, w, full)),
        np.asarray(res_ops.systematic_resample_batched(keys, w)),
    )


def test_masked_ancestors_from_u0_matches_batched_when_full():
    """The meshed ragged bank's shard-local inverse: explicit offsets +
    per-row counts, full counts == the dense batched form."""
    u0 = jax.random.uniform(jax.random.key(7), (3,), jnp.float32)
    w = jax.random.uniform(jax.random.key(8), (3, 512), jnp.float32)
    full = jnp.full((3,), 512, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(res_ops.systematic_ancestors_masked(u0, w, full)),
        np.asarray(res_ops.systematic_ancestors_batched(u0, w)),
    )
    # partial counts stay inside the prefix
    part = jnp.asarray([512, 100, 3], jnp.int32)
    anc = np.asarray(res_ops.systematic_ancestors_masked(u0, w, part))
    for i, n in enumerate([512, 100, 3]):
        assert (anc[i, :n] < n).all()


def test_masked_zero_count_rows_are_inert():
    """n_active = 0 rows must not crash or poison their neighbours."""
    x = jnp.asarray(
        [[1.0, 2.0, 3.0, 4.0], [jnp.nan, jnp.inf, -1.0, 0.0]], jnp.float32
    )
    n_act = jnp.asarray([4, 0], jnp.int32)
    w, m, lse = lse_ops.normalize_weights_masked(x, n_act)
    assert np.isfinite(np.asarray(w[0])).all()
    assert (np.asarray(w[1]) == 0.0).all()
    assert np.isneginf(float(lse[1])) and np.isneginf(float(m[1]))
    keys = jax.random.split(jax.random.key(9), 2)
    anc = np.asarray(
        res_ops.systematic_resample_masked(
            keys, jnp.abs(x).at[1].set(0.0), n_act
        )
    )
    assert ((anc >= 0) & (anc < 4)).all()


if given is not None:

    @given(st.integers(2, 2000))
    @settings(max_examples=20, deadline=None)
    def test_cumsum_kernel_property_random_sizes(n):
        w = jax.random.uniform(jax.random.key(n), (n,), jnp.float32)
        cs = res_ops.inclusive_cumsum(w)
        np.testing.assert_allclose(
            float(cs[-1]), float(jnp.sum(w)), rtol=1e-5
        )

    @given(
        st.integers(1, 1500),
        st.sampled_from(DTYPES),
    )
    @settings(max_examples=25, deadline=None)
    def test_masked_kernels_prefix_property(n, dt):
        """∀ n: masked row (junk tail) ≡ unmasked width-n kernels, bitwise."""
        width = 1536
        x = _junk_rows(jax.random.key(n), 1, width, [n], dt)
        n_act = jnp.asarray([n], jnp.int32)
        wm, mm, lsem = lse_ops.normalize_weights_masked(x, n_act)
        wi, mi, lsei = lse_ops.normalize_weights(x[0, :n])
        np.testing.assert_array_equal(
            np.asarray(wm[0, :n], np.float32), np.asarray(wi, np.float32)
        )
        np.testing.assert_array_equal(float(lsem[0]), float(lsei))
        key = jax.random.key(n + 1)
        w = jax.random.uniform(jax.random.key(n + 2), (width,), jnp.float32)
        ancm = np.asarray(
            res_ops.systematic_resample_masked(key[None], w[None], n_act)
        )
        anci = np.asarray(res_ops.systematic_resample(key, w[:n]))
        np.testing.assert_array_equal(ancm[0, :n], anci)
