"""Distributed particle filter (shard_map) on 8 forced host devices."""

import pytest

from tests._mp import run_with_devices

TRACK = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import FilterConfig, ParticleFilter, get_policy
from repro.core.tracking import TrackerConfig, make_tracker_spec
from repro.compat import make_mesh
from repro.data.synthetic_video import VideoConfig, generate_video

mesh = make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
video, truth = generate_video(jax.random.key(0),
                              VideoConfig(num_frames=25, height=128, width=128))
pol = get_policy("{policy}")
tcfg = TrackerConfig(num_particles=1024, height=128, width=128)
spec = make_tracker_spec(tcfg, pol)
flt = ParticleFilter(
    spec, FilterConfig(policy=pol, mesh=mesh, axis="data", scheme="{scheme}"))
state = flt.init(jax.random.key(1), 1024)
ests = []
for t in range(25):
    state, out = flt.jit_step(state, video[t], jax.random.key(100 + t))
    ests.append(np.asarray(out.estimate["pos"]))
log_w = state.log_weights
traj = np.stack(ests)
err = np.sqrt(np.mean(np.sum((traj - np.asarray(truth[:25]))**2, -1)))
assert np.isfinite(traj).all()
assert err < 3.0, err
# weight invariant for the exact scheme: globally normalized after each
# step (slack: 16-bit log-weights quantize, inflating the exp-sum).  The
# local scheme intentionally carries non-uniform per-shard mass (log of
# tiny local sums quantizes worse); its weights are only normalized at the
# *next* step's dist_normalize, so the invariant is scheme-specific.
if "{scheme}" == "exact":
    w_sum = float(jnp.sum(jnp.exp(log_w.astype(jnp.float32))))
    assert abs(w_sum - 1.0) < 1e-2, w_sum
print("rmse", err)
"""


@pytest.mark.parametrize("scheme", ["exact", "local"])
@pytest.mark.parametrize("policy", ["fp32", "fp16"])
def test_distributed_tracking(scheme, policy):
    out = run_with_devices(
        TRACK.format(scheme=scheme, policy=policy), devices=8
    )
    assert "rmse" in out


def test_exact_scheme_matches_single_device():
    """Same keys -> the distributed exact resampler tracks the same object
    with comparable accuracy to the single-device filter."""
    out = run_with_devices(TRACK.format(scheme="exact", policy="fp32"), devices=8)
    rmse = float(out.strip().split()[-1])
    assert rmse < 1.0, rmse


def test_distributed_config_validation():
    """Bad scheme/period/fraction/axis combinations fail at construction —
    a zero period or fraction would silently disable the RNA exchange."""
    from repro.core.distributed import DistributedConfig

    DistributedConfig(mesh=None)  # defaults are valid
    DistributedConfig(mesh=None, exchange_every=1, exchange_frac=1.0)
    with pytest.raises(KeyError, match="scheme"):
        DistributedConfig(mesh=None, scheme="gossip")
    with pytest.raises(ValueError, match="exchange_every"):
        DistributedConfig(mesh=None, exchange_every=0)
    with pytest.raises(ValueError, match="exchange_every"):
        DistributedConfig(mesh=None, exchange_every=-3)
    with pytest.raises(ValueError, match="exchange_frac"):
        DistributedConfig(mesh=None, exchange_frac=0.0)
    with pytest.raises(ValueError, match="exchange_frac"):
        DistributedConfig(mesh=None, exchange_frac=1.5)
    with pytest.raises(ValueError, match="bank_axis"):
        DistributedConfig(mesh=None, axis="model", bank_axis="model")
