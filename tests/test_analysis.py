"""The precision-contract analyzer, tested against its own history.

Each AST rule gets a minimal fixture reproducing the historical bug class it
was seeded by — the rule must fire on the fixture and stay silent on the
real tree (modulo baseline).  The jaxpr auditor must prove fp32 accumulation
on the real fused step under the half-precision policies, and must *fail*
when a violating fixture is traced through it.
"""

from __future__ import annotations

import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import RULES, load_baseline, run_lint, split_baseline
from repro.analysis.findings import Finding
from repro.analysis.jaxpr_audit import audit_closed_jaxpr, trace_step
from repro.analysis.lint import lint_file


def _lint(rel_path: str, code: str, rule_names=None):
    rules = (
        [RULES[n] for n in rule_names]
        if rule_names is not None
        else list(RULES.values())
    )
    return lint_file(
        rel_path, rel_path, rules=rules, source=textwrap.dedent(code)
    )


def _rules_fired(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# rule fixtures: one historical bug each


def test_shared_body_fires_on_forked_cumsum():
    """PR-5/7 class: a kernel file re-rolling the CDF instead of sharing
    kernels.common.cdf_block forks the bitwise contract."""
    findings = _lint(
        "src/repro/kernels/resample/newkernel.py",
        """
        import jax.numpy as jnp

        def my_cdf(w):
            return jnp.cumsum(w)

        def my_pick(cdf, u):
            return jnp.searchsorted(cdf, u)
        """,
    )
    assert _rules_fired(findings) == {"shared-body"}
    assert len(findings) == 2


def test_shared_body_fires_on_hand_rolled_lse():
    findings = _lint(
        "src/repro/kernels/logsumexp/newkernel.py",
        """
        import jax.numpy as jnp

        def my_lse(x):
            m = jnp.max(x)
            return m + jnp.log(jnp.sum(jnp.exp(x - m)))
        """,
    )
    assert _rules_fired(findings) == {"shared-body"}


def test_shared_body_silent_outside_kernels():
    findings = _lint(
        "src/repro/core/somewhere.py",
        "import jax.numpy as jnp\n\ndef f(w):\n    return jnp.cumsum(w)\n",
        rule_names=["shared-body"],
    )
    assert findings == []


def test_masked_grid_fires_on_dense_grid():
    """PR-4 class: a dense 1/P u-grid under a lane mask never samples the
    top of the active CDF."""
    findings = _lint(
        "src/repro/core/newresampler.py",
        """
        import jax.numpy as jnp

        def bad_masked(key, w, n_active):
            p = w.shape[-1]
            u = (jnp.arange(p) + 0.5) / p
            return u
        """,
        rule_names=["masked-grid"],
    )
    assert _rules_fired(findings) == {"masked-grid"}


def test_masked_grid_silent_on_count_aware_grid():
    findings = _lint(
        "src/repro/core/newresampler.py",
        """
        import jax.numpy as jnp

        def good_masked(key, w, n_active):
            p = w.shape[-1]
            u = (jnp.arange(p) + 0.5) / jnp.maximum(n_active, 1)
            return u
        """,
        rule_names=["masked-grid"],
    )
    assert findings == []


def test_masked_grid_sees_vmapped_row_closure():
    """The repo's own idiom: the count rebinds to a short name inside the
    per-row closure — that division is count-aware, not dense."""
    findings = _lint(
        "src/repro/core/newresampler.py",
        """
        import jax.numpy as jnp

        def banked(keys, w, n_active):
            p = w.shape[-1]

            def row(key, wr, n):
                return (jnp.arange(p) + 0.5) / jnp.maximum(n, 1)

            return row
        """,
        rule_names=["masked-grid"],
    )
    assert findings == []


def test_donation_safety_fires_on_escaping_view():
    """PR-5 retire pin: np.asarray views escaping the scheduler keep the
    donated bank buffers alive."""
    findings = _lint(
        "src/repro/launch/newsched.py",
        """
        import numpy as np

        def retire(results, x, y):
            results.append(np.asarray(x))
            return {"traj": np.asarray(y)}
        """,
        rule_names=["donation-safety"],
    )
    assert _rules_fired(findings) == {"donation-safety"}
    assert len({f.line for f in findings}) == 2  # both escape sites


def test_donation_safety_silent_on_local_view_and_copy():
    findings = _lint(
        "src/repro/launch/newsched.py",
        """
        import numpy as np

        def tick(results, x, buf, tok, i):
            done = np.asarray(x) > 0          # local temporary: fine
            buf[:, i] = np.asarray(tok)       # numpy subscript-store copies
            results.append(np.array(x))       # explicit copy at escape
            return int(done.sum())
        """,
        rule_names=["donation-safety"],
    )
    assert findings == []


def test_host_log_fires_on_host_and_folded_log():
    """PR-4 class: host math.log / folded jnp.log(<const>) are extra
    roundings of -log(n) beside the blessed engine path."""
    findings = _lint(
        "src/repro/core/newmod.py",
        """
        import math
        import jax.numpy as jnp

        def f(p):
            a = math.log(64)
            b = jnp.log(float(64))
            c = jnp.log(p)  # runtime log of a traced value: fine
            return a, b, c
        """,
        rule_names=["host-log"],
    )
    assert len(findings) == 2
    assert _rules_fired(findings) == {"host-log"}


def test_dtype_literal_fires_outside_blessed_modules():
    bad = _lint(
        "src/repro/core/newmod.py",
        "import jax.numpy as jnp\nX = jnp.float16\n",
        rule_names=["dtype-literal"],
    )
    blessed = _lint(
        "src/repro/core/precision.py",
        "import jax.numpy as jnp\nX = jnp.float16\n",
        rule_names=["dtype-literal"],
    )
    assert _rules_fired(bad) == {"dtype-literal"}
    assert blessed == []


def test_pragma_suppresses_with_justification_only():
    code = """
    import jax.numpy as jnp

    # analysis: allow(dtype-literal): fixture says so
    X = jnp.float16
    Y = jnp.bfloat16  # analysis: allow(dtype-literal)
    """
    findings = _lint(
        "src/repro/core/newmod.py", code, rule_names=["dtype-literal"]
    )
    # X is suppressed; Y's pragma has no justification, so the dtype
    # finding survives AND the bare pragma is itself reported.
    assert _rules_fired(findings) == {"dtype-literal", "pragma"}
    assert all("X = " not in f.snippet for f in findings)  # X suppressed


def test_registry_completeness_fires_on_orphan_resampler():
    """PR-4/6/7 class: a resampler poked into RESAMPLERS without masked or
    fused twins is a lint failure, not a 3 a.m. serve crash."""
    from repro.core import resampling

    rule = RULES["registry-completeness"]
    assert rule.check_repo() == []  # live registries are closed
    resampling.RESAMPLERS["_fixture_orphan"] = lambda k, w, p: None
    try:
        findings = rule.check_repo()
    finally:
        del resampling.RESAMPLERS["_fixture_orphan"]
    assert findings
    assert all("_fixture_orphan" in f.message for f in findings)
    assert {"MASKED_RESAMPLERS", "FUSED_EPILOGUES", "FUSED_STEPS"} <= {
        m for f in findings for m in f.message.split() if m.isupper()
    }


def test_fingerprint_survives_line_drift():
    a = Finding(rule="r", path="p.py", line=10, message="m", snippet="x = 1")
    b = Finding(rule="r", path="p.py", line=99, message="m", snippet="x = 1")
    assert a.fingerprint == b.fingerprint


# ---------------------------------------------------------------------------
# the real tree is clean (modulo baseline)


def test_real_tree_lint_clean_modulo_baseline():
    new, _ = split_baseline(run_lint(), load_baseline())
    assert new == [], "\n".join(f.format() for f in new)


# ---------------------------------------------------------------------------
# jaxpr auditor


def _fixture_jaxpr(fn, *avals):
    return jax.make_jaxpr(fn)(*avals)


def test_auditor_flags_half_accumulation_fixture():
    j = _fixture_jaxpr(jnp.cumsum, jnp.ones((8,), jnp.float16))
    findings = audit_closed_jaxpr(j, "fixture", strict=True)
    assert _rules_fired(findings) == {"jaxpr-half-accum"}


def test_auditor_flags_half_scan_carry_fixture():
    def body(x):
        def step(c, xi):
            return (c + xi).astype(jnp.float16), c

        return jax.lax.scan(step, jnp.float16(0), x)

    j = _fixture_jaxpr(body, jnp.ones((8,), jnp.float16))
    findings = audit_closed_jaxpr(j, "fixture", strict=True)
    assert "jaxpr-half-accum" in _rules_fired(findings)
    assert any("scan carry" in f.message for f in findings)


def test_auditor_flags_unmediated_but_passes_mediated_explog():
    def naive(x):
        return jnp.exp(x) / jnp.sum(jnp.exp(x).astype(jnp.float32))

    def mediated(x):
        m = jnp.max(x)
        return jnp.exp(x - m)

    x = jnp.ones((8,), jnp.float16)
    bad = audit_closed_jaxpr(_fixture_jaxpr(naive, x), "f", strict=False)
    good = audit_closed_jaxpr(_fixture_jaxpr(mediated, x), "f", strict=False)
    assert "jaxpr-half-explog" in _rules_fired(bad)
    assert good == []


@pytest.mark.parametrize("pname", ["fp16_mixed", "bf16_mixed"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_step_accumulates_fp32_under_half_policies(pname, backend):
    """The acceptance criterion: under the half-precision policies, every
    reduction and scan carry in the real (fused, on pallas) step runs fp32
    — proven on the jaxpr, not inferred from tolerances."""
    closed = trace_step(pname, backend)
    findings = audit_closed_jaxpr(closed, f"step:{backend}:{pname}")
    assert findings == [], "\n".join(f.format() for f in findings)


def test_pure_half_pallas_kernels_stay_fp32_inside():
    """Pure fp16/bf16 on pallas: kernel interiors strict, engine-level
    transcendentals must be stability-mediated."""
    for pname in ("fp16", "bf16"):
        closed = trace_step(pname, "pallas")
        findings = audit_closed_jaxpr(
            closed, f"step:pallas:{pname}", strict=False
        )
        assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_check_passes_on_shipped_tree():
    from repro.analysis.__main__ import main

    assert main(["--no-jaxpr", "--check", "-q"]) == 0
    assert main(["--rules"]) == 0
