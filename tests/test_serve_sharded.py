"""Sharded-serving correctness: context-parallel decode == single-device.

The optimized serving defaults shard the KV cache sequence over whatever
mesh axes the batch leaves free (params.SERVE_RULES cache_seq) and pin the
cache layout in decode.  The distributed attention then reduces over a
seq-sharded cache — the paper's Eq.-5 online-LSE as a collective.  These
tests assert the sharded step is numerically identical to the unsharded
reference.
"""

import pytest

from tests._mp import run_with_devices

SNIPPET = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, reduced_config
from repro.core.precision import get_policy
from repro.models import model as M
from repro.models.params import SERVE_RULES, tree_shardings, abstract_params

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
jax.set_mesh(mesh)

cfg = reduced_config(get_config("{arch}"))
pol = get_policy("fp32")
B, S = 4, 32
params = M.init_params(jax.random.key(1), cfg, jnp.float32)
toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)

# reference: plain single-placement decode
cache_ref = M.init_cache(cfg, B, S, jnp.float32)
ref = []
for i in range(S):
    lg, cache_ref = M.decode_step(params, toks[:, i], jnp.int32(i), cache_ref, cfg, pol)
    ref.append(lg)
ref = jnp.stack(ref, 1)

# sharded: serve-rule placements for params and cache (seq-sharded cache)
p_shard = tree_shardings(mesh, M.param_specs(cfg), SERVE_RULES)
params_s = jax.device_put(params, p_shard)
cspecs = M.cache_specs(cfg, B, S)
from repro.models.params import ParamSpec
c_shard = tree_shardings(mesh, cspecs, SERVE_RULES)
cache = jax.tree.map(
    lambda s: jnp.zeros(s.shape, jnp.float32),
    cspecs, is_leaf=lambda x: isinstance(x, ParamSpec))
cache = jax.device_put(cache, c_shard)
step = jax.jit(lambda p, t, i, c: M.decode_step(p, t, i, c, cfg, pol),
               donate_argnums=(3,))
out = []
for i in range(S):
    lg, cache = step(params_s, toks[:, i], jnp.int32(i), cache)
    out.append(lg)
out = jnp.stack(out, 1)

d = float(jnp.max(jnp.abs(out - ref)))
assert d < 1e-3, d
print("max|sharded - reference| =", d)
"""


@pytest.mark.parametrize("arch", ["minitron-8b", "gemma3-27b", "zamba2-2.7b"])
def test_context_parallel_decode_matches_reference(arch):
    out = run_with_devices(SNIPPET.format(arch=arch), devices=8, timeout=560)
    assert "max|sharded" in out
